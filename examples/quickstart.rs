//! Quickstart — the paper's Figure 1 workflow, end to end.
//!
//! Starts a HOPAAS server in-process, connects a client over real HTTP,
//! and runs the full optimization loop against the Branin function:
//!
//! ```text
//!   client                      server
//!     | -- POST /api/ask/{t} ---> |   (join/create study, suggest params)
//!     |            train ...      |
//!     | -- POST /api/should_prune |   (report step loss; prune?)
//!     |            ...            |
//!     | -- POST /api/tell/{t} --> |   (final objective)
//! ```
//!
//! Run: `cargo run --release --example quickstart`

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::Objective;
use hopaas::worker::{HopaasClient, StudySpec};

fn main() -> anyhow::Result<()> {
    // 1. A server with auth on — exactly what `hopaas serve` runs.
    let server = HopaasServer::start("127.0.0.1:0", HopaasConfig::default())?;
    println!("server    : http://{}", server.addr());
    println!("dashboard : http://{}/", server.addr());

    // 2. A client holding an API token (issued at startup here; the
    //    `/api/token` endpoint mints more).
    let mut client = HopaasClient::connect(server.addr(), server.bootstrap_token.clone())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!("version   : {}", client.version().map_err(|e| anyhow::anyhow!(e.to_string()))?);

    // 3. The study definition travels with every ask — any node posting
    //    the same definition joins the same study.
    let spec = StudySpec::new("quickstart-branin")
        .properties_json(Objective::Branin.properties())
        .sampler("tpe")
        .pruner("median")
        .from_node("quickstart-node");

    let mut best = f64::INFINITY;
    let mut best_params = String::new();
    let trials = 60;
    let mut pruned_count = 0;
    for i in 0..trials {
        let trial = client.ask(&spec).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let value = Objective::Branin.eval_params(&trial.params);

        // Simulated "training": interim losses converge toward the final
        // value; the server's median pruner kills hopeless trials early.
        let mut pruned = false;
        for step in 1..=8u64 {
            let interim = value + 3.0 / step as f64;
            if client
                .should_prune(&trial, step, interim)
                .map_err(|e| anyhow::anyhow!(e.to_string()))?
            {
                pruned = true;
                pruned_count += 1;
                break;
            }
        }
        if pruned {
            continue;
        }
        let is_best = client
            .tell(&trial, value)
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        if is_best {
            best = value;
            best_params = trial.params.to_string();
            println!("trial {i:>3}: new best {best:.5}  params={best_params}");
        }
    }

    println!(
        "\nbest after {trials} trials: {best:.5}   (Branin f* = 0.39789)  pruned={pruned_count}"
    );
    println!("best params: {best_params}");
    assert!(best < 2.0, "TPE should get close to the Branin optimum");
    server.stop();
    Ok(())
}

//! Pruning demo (E5 companion): shows how much compute `should_prune`
//! saves on simulated learning curves, per pruner.
//!
//! For each pruner, 150 trials × up to 60 steps run against a fresh
//! in-process server. The printed table shows total steps executed
//! (compute spent), the fraction saved vs no pruning, and the best final
//! loss found — the trade-off the paper's §2 describes: "abort
//! non-promising trials (pruning) without wasting computing power".
//!
//! Run: `cargo run --release --example pruning_demo`

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::json::Value;
use hopaas::objectives::LearningCurve;
use hopaas::rng::Rng;
use hopaas::worker::{HopaasClient, StudySpec};

const TRIALS: u64 = 150;
const MAX_STEPS: u64 = 60;

fn run_with_pruner(pruner: Option<&str>) -> anyhow::Result<(u64, u64, f64)> {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )?;
    let mut client = HopaasClient::connect(server.addr(), "x".into())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    let mut spec = StudySpec::new(&format!("prune-{}", pruner.unwrap_or("none")))
        .uniform("quality", 0.0, 1.0)
        .sampler("random"); // isolate the pruner's effect
    if let Some(p) = pruner {
        let mut cfg = Value::obj();
        cfg.set("name", p);
        if p == "median" || p == "percentile" {
            cfg.set("warmup_steps", 3).set("min_trials", 5);
        }
        spec = spec.pruner_json(Value::Obj(cfg));
    }

    let mut rng = Rng::new(7);
    let mut steps_total = 0u64;
    let mut pruned_total = 0u64;
    let mut best = f64::INFINITY;
    for _ in 0..TRIALS {
        let trial = client.ask(&spec).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let quality = trial.params.get("quality").as_f64().unwrap();
        let curve = LearningCurve::from_quality(quality, &mut rng);
        let mut pruned = false;
        for step in 1..=MAX_STEPS {
            steps_total += 1;
            let loss = curve.at(step, &mut rng);
            if client
                .should_prune(&trial, step, loss)
                .map_err(|e| anyhow::anyhow!(e.to_string()))?
            {
                pruned = true;
                pruned_total += 1;
                break;
            }
        }
        if !pruned {
            let final_loss = curve.final_loss();
            client.tell(&trial, final_loss).map_err(|e| anyhow::anyhow!(e.to_string()))?;
            best = best.min(final_loss);
        }
    }
    server.stop();
    Ok((steps_total, pruned_total, best))
}

fn main() -> anyhow::Result<()> {
    println!(
        "{TRIALS} trials × ≤{MAX_STEPS} steps, random search, simulated learning curves\n"
    );
    println!(
        "{:<12} {:>12} {:>10} {:>10} {:>12}",
        "pruner", "steps", "saved", "pruned", "best loss"
    );
    let (full_steps, _, _) = run_with_pruner(None)?;
    println!(
        "{:<12} {:>12} {:>10} {:>10} {:>12}",
        "none", full_steps, "—", 0, format!("{:.4}", run_with_pruner(None)?.2)
    );
    for pruner in ["median", "percentile", "sha", "hyperband", "patient"] {
        let (steps, pruned, best) = run_with_pruner(Some(pruner))?;
        println!(
            "{:<12} {:>12} {:>9.1}% {:>10} {:>12.4}",
            pruner,
            steps,
            100.0 * (full_steps.saturating_sub(steps)) as f64 / full_steps as f64,
            pruned,
            best
        );
    }
    println!("\nPruners cut compute sharply at (near-)zero cost in final quality.");
    Ok(())
}

//! Dashboard server — run a HOPAAS server with live traffic and drive
//! the read path the way a busy dashboard would: cursor-paginated study
//! and trial listings, the `/best` incumbent probe, and the long-poll
//! `/events` trial feed, all served from epoch-stamped materialized
//! views (no shard locks on any read).
//!
//! Open the printed URL in a browser for the classic auto-refreshing
//! UI; meanwhile this process tails one study's event feed and prints
//! every completion/prune as it lands, then dumps a paginated read of
//! the final state before exiting.
//!
//! Run: `cargo run --release --example dashboard_server -- --duration 30`

use hopaas::config::Args;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::http::Client;
use hopaas::objectives::Objective;
use hopaas::worker::Campaign;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let duration = args.get_u64("duration", 30);
    let addr = args.get_or("addr", "127.0.0.1:8021").to_string();

    let server = HopaasServer::start(
        &addr,
        HopaasConfig {
            auth_required: false,
            // Short poll window so the example's feed tail stays lively.
            events_poll_timeout: Duration::from_secs(2),
            ..Default::default()
        },
    )?;
    println!("dashboard: http://{}/", server.addr());
    println!("metrics:   http://{}/metrics", server.addr());
    println!("paginated: http://{}/api/studies?limit=10", server.addr());
    println!("serving traffic for {duration}s ...");

    // Background traffic: a slow-ticking campaign per objective, each
    // with a couple of simulated dashboard viewers of its own.
    let stop = Arc::new(AtomicBool::new(false));
    let mut feeders = Vec::new();
    for (i, objective) in [Objective::Branin, Objective::Ackley, Objective::Rastrigin]
        .into_iter()
        .enumerate()
    {
        let addr = server.addr();
        let stop = stop.clone();
        feeders.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let mut c = Campaign::new(addr, "x".into(), objective);
                c.n_nodes = 4;
                c.max_trials = 16;
                c.steps_per_trial = 10;
                c.step_cost_us = 20_000; // visibly live curves
                c.seed = 42 + i as u64;
                c.viewers = 2;
                let _ = c.run();
            }
        }));
    }

    // Foreground: tail the first study's live event feed over the
    // long-poll API until the duration runs out.
    let mut client = Client::connect(server.addr())?;
    client.set_timeout(Duration::from_secs(10));
    let deadline = Instant::now() + Duration::from_secs(duration);
    let mut watermark = 0u64;
    let mut study: Option<u64> = None;
    while Instant::now() < deadline {
        let Some(sid) = study else {
            // Wait for the first study to appear in the paginated list.
            let page = client.get("/api/studies?limit=1")?.json_body()?;
            study = page.get("studies").at(0).get("id").as_u64();
            if study.is_none() {
                std::thread::sleep(Duration::from_millis(50));
            }
            continue;
        };
        let feed = client
            .get(&format!("/api/studies/{sid}/events?since={watermark}&timeout=2"))?
            .json_body()?;
        if let Some(w) = feed.get("watermark").as_u64() {
            watermark = w;
        }
        for e in feed.get("events").as_arr().unwrap_or(&[]) {
            println!(
                "event #{:<4} trial {:<4} {:<9} value={}",
                e.get("seq"),
                e.get("trial_id"),
                e.get("kind").as_str().unwrap_or("?"),
                e.get("value"),
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    for f in feeders {
        let _ = f.join();
    }

    // Final state via the paginated read path: one page of studies,
    // each study's incumbent, and a cursor walk over its trials.
    let list = client.get("/api/studies?limit=10")?.json_body()?;
    for s in list.get("studies").as_arr().unwrap_or(&[]) {
        let sid = s.get("id").as_u64().unwrap_or(0);
        let best = client.get(&format!("/api/studies/{sid}/best"))?.json_body()?;
        let mut n_trials = 0usize;
        let mut path = format!("/api/studies/{sid}/trials?limit=50");
        loop {
            let page = client.get(&path)?.json_body()?;
            n_trials += page.get("trials").as_arr().map_or(0, |t| t.len());
            match page.get("next_cursor").as_str() {
                Some(c) => path = format!("/api/studies/{sid}/trials?limit=50&cursor={c}"),
                None => break,
            }
        }
        println!(
            "study {sid} '{}': {} trials paged, epoch {}, best={}",
            s.get("name").as_str().unwrap_or("?"),
            n_trials,
            s.get("epoch"),
            best.get("best_value"),
        );
    }
    println!("done.");
    server.stop();
    Ok(())
}

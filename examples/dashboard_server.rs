//! Dashboard server — run a HOPAAS server with live traffic so the web
//! UI has something to show, then keep serving until the duration ends.
//!
//! Open the printed URL in a browser: the study table and loss curves
//! refresh every 2 s from the same data APIs the paper's Chartist UI
//! polls.
//!
//! Run: `cargo run --release --example dashboard_server -- --duration 60`

use hopaas::config::Args;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::Objective;
use hopaas::worker::Campaign;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let duration = args.get_u64("duration", 30);
    let addr = args.get_or("addr", "127.0.0.1:8021").to_string();

    let server = HopaasServer::start(
        &addr,
        HopaasConfig { auth_required: false, ..Default::default() },
    )?;
    println!("dashboard: http://{}/", server.addr());
    println!("metrics:   http://{}/metrics", server.addr());
    println!("serving traffic for {duration}s ...");

    // Background traffic: a slow-ticking campaign per objective.
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut feeders = Vec::new();
    for (i, objective) in [Objective::Branin, Objective::Ackley, Objective::Rastrigin]
        .into_iter()
        .enumerate()
    {
        let addr = server.addr();
        let stop = stop.clone();
        feeders.push(std::thread::spawn(move || {
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let mut c = Campaign::new(addr, "x".into(), objective);
                c.n_nodes = 4;
                c.max_trials = 16;
                c.steps_per_trial = 10;
                c.step_cost_us = 20_000; // visibly live curves
                c.seed = 42 + i as u64;
                let _ = c.run();
            }
        }));
    }

    std::thread::sleep(std::time::Duration::from_secs(duration));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for f in feeders {
        let _ = f.join();
    }
    println!("done.");
    server.stop();
    Ok(())
}

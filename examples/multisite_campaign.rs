//! Multi-site campaign — the paper's §4 deployment at laptop scale.
//!
//! "HOPAAS was able to coordinate dozens of optimization studies with
//! hundreds of trials on each study from more than twenty concurrent and
//! diverse computing nodes."
//!
//! This example starts ONE durable server and runs several studies
//! concurrently, each driven by a 24-node fleet spanning the four site
//! profiles (MARCONI 100-like HPC, INFN Cloud, private, commercial
//! spot). Sites differ in speed, preemption rate and network jitter;
//! trials from vanished spot nodes are reaped by the server. Per-study
//! summaries and per-site attribution are printed at the end — the same
//! numbers the dashboard's study table shows.
//!
//! Run: `cargo run --release --example multisite_campaign`
//!      (flags: --studies N --nodes N --trials N)

use hopaas::config::Args;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::Objective;
use hopaas::worker::Campaign;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_studies = args.get_u64("studies", 6) as usize;
    let n_nodes = args.get_u64("nodes", 24) as usize;
    let max_trials = args.get_u64("trials", 120);

    let data_dir = std::env::temp_dir().join(format!("hopaas-campaign-{}", std::process::id()));
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig {
            auth_required: false,
            data_dir: Some(data_dir.clone()),
            engine: hopaas::coordinator::engine::EngineConfig {
                reap_after: Some(5.0),
                ..Default::default()
            },
            ..Default::default()
        },
    )?;
    println!(
        "server http://{} (durable storage: {})",
        server.addr(),
        data_dir.display()
    );

    // Dozens of studies: one per (objective, sampler) pair, all running
    // against the same server at once.
    let mixes: Vec<(Objective, &'static str)> = hopaas::objectives::ALL
        .into_iter()
        .zip(["tpe", "tpe", "gp", "cmaes", "tpe", "random", "tpe"])
        .take(n_studies)
        .collect();

    let t0 = std::time::Instant::now();
    let handles: Vec<_> = mixes
        .into_iter()
        .enumerate()
        .map(|(i, (objective, sampler))| {
            let addr = server.addr();
            std::thread::spawn(move || {
                let mut c = Campaign::new(addr, "x".into(), objective);
                c.study_name = format!("campaign-{}-{}", objective.name(), sampler);
                c.sampler = sampler;
                c.n_nodes = n_nodes;
                c.max_trials = max_trials;
                c.steps_per_trial = 15;
                c.step_cost_us = 150;
                c.seed = 100 + i as u64;
                (objective, sampler, c.run())
            })
        })
        .collect();

    println!(
        "\n{:<28} {:>9} {:>7} {:>9} {:>10} {:>12}",
        "study", "completed", "pruned", "preempted", "best", "f*"
    );
    let mut total_trials = 0;
    for h in handles {
        let (objective, sampler, result) = h.join().expect("campaign thread");
        let report = result.map_err(|e| anyhow::anyhow!(e.to_string()))?;
        total_trials += report.completed + report.pruned + report.preempted;
        println!(
            "{:<28} {:>9} {:>7} {:>9} {:>10.4} {:>12.4}",
            format!("{}/{}", objective.name(), sampler),
            report.completed,
            report.pruned,
            report.preempted,
            report.best.unwrap_or(f64::NAN),
            objective.f_star(),
        );
    }
    let wall = t0.elapsed();
    println!(
        "\n{} studies × {} nodes: {} trials in {:.1}s ({:.1} trials/s) across sites:",
        n_studies,
        n_nodes,
        total_trials,
        wall.as_secs_f64(),
        total_trials as f64 / wall.as_secs_f64()
    );

    // Site attribution from the server's own records.
    let reaped = server.engine.reap_stale();
    println!("server reaped {reaped} stale trial(s) from preempted nodes");
    let studies = server.engine.studies_json();
    let mut completed_total = 0;
    for s in studies.as_arr().unwrap_or(&[]) {
        completed_total += s.get("n_completed").as_i64().unwrap_or(0);
    }
    println!(
        "server sees {} studies, {} completed trials — all recovered from WAL on restart",
        studies.as_arr().map(|a| a.len()).unwrap_or(0),
        completed_total
    );
    server.stop();
    let _ = std::fs::remove_dir_all(&data_dir);
    Ok(())
}

//! Microbenchmark of the PJRT GAN hot path: compile time, per-step
//! latency and evaluation latency per compiled variant. Feeds the L1/L2
//! rows of EXPERIMENTS.md §Perf.
//!
//! Run: `make artifacts && cargo run --release --example gan_timing`

use hopaas::gan::{GanHyper, GanTrainer};
use hopaas::runtime::Runtime;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(
        Runtime::open(Runtime::default_dir())
            .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?,
    );
    println!("platform: {}\n", rt.platform());
    println!(
        "{:<10} {:>14} {:>12} {:>12} {:>14}",
        "variant", "compile", "per-step", "eval", "steps/s"
    );
    let variants: Vec<(u64, u64)> = rt
        .manifest
        .variants
        .iter()
        .map(|v| (v.width, v.depth))
        .collect();
    for (w, d) in variants {
        let mut t = GanTrainer::new(rt.clone(), w, d, 1)?;
        let hp = GanHyper::default();
        let t0 = Instant::now();
        t.train(1, &hp)?; // includes compile
        let compile = t0.elapsed();
        let n = 30;
        let t0 = Instant::now();
        t.train(n, &hp)?;
        let per = t0.elapsed() / n as u32;
        let t0 = Instant::now();
        let _ = t.evaluate()?;
        let eval = t0.elapsed();
        println!(
            "{:<10} {:>14.2?} {:>12.2?} {:>12.2?} {:>14.1}",
            format!("{w}x{d}"),
            compile,
            per,
            eval,
            1.0 / per.as_secs_f64()
        );
    }
    Ok(())
}

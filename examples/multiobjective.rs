//! Multi-objective optimization over the HOPAAS protocol — the paper's
//! §5 future work ("introduce support to multi-objective optimizations")
//! as a working feature.
//!
//! A study declares `"direction": ["minimize", "minimize"]`; workers
//! `tell` objective *vectors*; the server runs NSGA-II and tracks the
//! Pareto front, served at `/api/studies/{id}/pareto`.
//!
//! Run: `cargo run --release --example multiobjective`

use hopaas::coordinator::mo::hypervolume;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::multi::MoProblem;
use hopaas::worker::{HopaasClient, StudySpec};

fn main() -> anyhow::Result<()> {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )?;
    let mut client = HopaasClient::connect(server.addr(), "mo".into())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;

    let problem = MoProblem::Zdt1;
    let spec = StudySpec::new("zdt1-pareto")
        .properties_json(problem.properties())
        .directions(&["minimize", "minimize"])
        .sampler("nsga2");

    println!("optimizing {} (bi-objective, d={}) with NSGA-II ...", problem.name(), problem.dim());
    let mut study_id = 0;
    let mut points = Vec::new();
    for i in 0..250 {
        let trial = client.ask(&spec).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        study_id = trial.study_id;
        let [f1, f2] = problem.eval_params(&trial.params);
        let on_front = client
            .tell_values(&trial, &[f1, f2])
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        points.push(vec![f1, f2]);
        if i % 50 == 49 {
            let hv = hypervolume(&points, &problem.hv_reference(), 0);
            println!("  after {:>3} trials: hypervolume {:.3} (last trial on front: {})", i + 1, hv, on_front);
        }
    }

    let front = client
        .pareto(study_id)
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    let front = front.as_arr().unwrap().to_vec();
    println!("\nPareto front ({} trials) — f1 vs f2 (true front: f2 = 1 - sqrt(f1)):", front.len());
    let mut pts: Vec<(f64, f64)> = front
        .iter()
        .map(|t| {
            let v = t.get("values");
            (v.at(0).as_f64().unwrap(), v.at(1).as_f64().unwrap())
        })
        .collect();
    pts.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (f1, f2) in pts.iter().take(20) {
        let ideal = 1.0 - f1.sqrt();
        println!("  f1={f1:.3}  f2={f2:.3}  (front would be {ideal:.3})");
    }
    server.stop();
    Ok(())
}

//! END-TO-END DRIVER (E6): HOPAAS-orchestrated hyperparameter
//! optimization of the real GAN workload, all three layers composing:
//!
//!   L3  HOPAAS server + worker fleet over real HTTP (this binary)
//!   L2  JAX train/eval graph, AOT-compiled to HLO (`make artifacts`)
//!   L1  Pallas fused-dense kernels inside that graph
//!
//! Each trial: the worker asks HOPAAS for hyperparameters — two
//! architecture choices (width, depth → compiled variant) and five
//! continuous ones (lr_g, lr_d, beta1, beta2, leak) — trains the GAN via
//! PJRT, reports the Wasserstein-1 objective periodically for pruning,
//! and tells the final value. The baseline is the default configuration
//! (the "previous results" of §4); the campaign should beat it.
//!
//! Results are recorded in EXPERIMENTS.md §E6.
//!
//! Run: `make artifacts && cargo run --release --example gan_hpo`
//!      (flags: --trials N --workers N --steps N)

use hopaas::config::Args;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::gan::{GanHyper, GanTrainer};
use hopaas::json::Value;
use hopaas::runtime::Runtime;
use hopaas::worker::{HopaasClient, StudySpec, WorkerError};
use std::sync::Arc;

fn spec() -> StudySpec {
    StudySpec::new("lamarr-gan-pid")
        .categorical("width", vec![Value::Num(32.0), Value::Num(64.0), Value::Num(128.0)])
        .categorical("depth", vec![Value::Num(2.0), Value::Num(3.0)])
        .loguniform("lr_g", 1e-4, 1e-2)
        .loguniform("lr_d", 1e-4, 1e-2)
        .uniform("beta1", 0.3, 0.9)
        .uniform("beta2", 0.8, 0.999)
        .uniform("leak", 0.05, 0.3)
        .sampler("tpe")
        .pruner_json({
            let mut p = Value::obj();
            p.set("name", "median").set("warmup_steps", 1).set("min_trials", 4);
            Value::Obj(p)
        })
}

/// Run one GAN trial: train in chunks, report after each chunk.
fn run_trial(
    client: &mut HopaasClient,
    runtime: &Arc<Runtime>,
    trial: &hopaas::worker::TrialHandle,
    total_steps: u64,
    chunks: u64,
) -> Result<Option<f64>, WorkerError> {
    let p = &trial.params;
    let width = p.get("width").as_f64().unwrap_or(64.0) as u64;
    let depth = p.get("depth").as_f64().unwrap_or(2.0) as u64;
    let hp = GanHyper {
        lr_g: p.get("lr_g").as_f64().unwrap_or(1e-3) as f32,
        lr_d: p.get("lr_d").as_f64().unwrap_or(1e-3) as f32,
        beta1: p.get("beta1").as_f64().unwrap_or(0.5) as f32,
        beta2: p.get("beta2").as_f64().unwrap_or(0.9) as f32,
        leak: p.get("leak").as_f64().unwrap_or(0.1) as f32,
    };
    let mut trainer = GanTrainer::new(runtime.clone(), width, depth, trial.trial_id)
        .map_err(|e| WorkerError::Api { status: 500, detail: e.to_string() })?;

    let chunk = total_steps / chunks;
    for step in 1..=chunks {
        trainer
            .train(chunk, &hp)
            .map_err(|e| WorkerError::Api { status: 500, detail: e.to_string() })?;
        let w1 = trainer
            .evaluate_with_leak(hp.leak)
            .map_err(|e| WorkerError::Api { status: 500, detail: e.to_string() })?
            as f64;
        if client.should_prune(trial, step, w1)? {
            return Ok(None); // pruned
        }
    }
    let final_w1 = trainer
        .evaluate_with_leak(hp.leak)
        .map_err(|e| WorkerError::Api { status: 500, detail: e.to_string() })?
        as f64;
    client.tell(trial, final_w1)?;
    Ok(Some(final_w1))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_trials = args.get_u64("trials", 36);
    let n_workers = args.get_u64("workers", 3) as usize;
    let total_steps = args.get_u64("steps", 240);
    let chunks = 4u64;

    let runtime = Arc::new(
        Runtime::open(Runtime::default_dir())
            .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?,
    );
    println!(
        "PJRT platform: {} | {} compiled variants available",
        runtime.platform(),
        runtime.manifest.variants.len()
    );

    // Baseline: the default ("previous") configuration at 64x2.
    println!("training baseline (default hyperparameters, 64x2)...");
    let mut baseline_trainer = GanTrainer::new(runtime.clone(), 64, 2, 0)?;
    let hp0 = GanHyper::default();
    baseline_trainer.train(total_steps, &hp0)?;
    let baseline = baseline_trainer.evaluate_with_leak(hp0.leak)? as f64;
    println!("baseline W1 = {baseline:.5}\n");

    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )?;
    println!(
        "HOPAAS on http://{} — {} workers × {} trials × {} steps",
        server.addr(),
        n_workers,
        n_trials,
        total_steps
    );

    let t0 = std::time::Instant::now();
    let counter = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let handles: Vec<_> = (0..n_workers)
        .map(|w| {
            let addr = server.addr();
            let runtime = runtime.clone();
            let counter = counter.clone();
            std::thread::spawn(move || -> Result<(u64, u64), WorkerError> {
                let mut client = HopaasClient::connect(addr, "x".into())?;
                let spec = spec().from_node(&format!("gan-worker-{w}"));
                let (mut done, mut pruned) = (0u64, 0u64);
                loop {
                    let n = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if n >= n_trials {
                        return Ok((done, pruned));
                    }
                    let trial = client.ask(&spec)?;
                    match run_trial(&mut client, &runtime, &trial, total_steps, chunks)? {
                        Some(w1) => {
                            done += 1;
                            println!(
                                "  trial {:>3} ({}x{} lr_g={:.1e}) -> W1 {:.5}",
                                trial.trial_number,
                                trial.params.get("width"),
                                trial.params.get("depth"),
                                trial.params.get("lr_g").as_f64().unwrap_or(0.0),
                                w1
                            );
                        }
                        None => {
                            pruned += 1;
                            println!("  trial {:>3} pruned", trial.trial_number);
                        }
                    }
                }
            })
        })
        .collect();

    let (mut completed, mut pruned) = (0, 0);
    for h in handles {
        let (d, p) = h.join().expect("worker").map_err(|e| anyhow::anyhow!(e.to_string()))?;
        completed += d;
        pruned += p;
    }
    let wall = t0.elapsed();

    // Pull the best-so-far curve from the server (the dashboard's data).
    let studies = server.engine.studies_json();
    let study_id = studies.at(0).get("id").as_u64().unwrap();
    let best_curve = server.engine.best_curve(study_id).unwrap();
    let best = best_curve.last().map(|&(_, v)| v).unwrap_or(f64::NAN);

    println!("\nbest-so-far curve (trial -> best W1):");
    let mut last = f64::INFINITY;
    for (n, v) in &best_curve {
        if *v < last {
            println!("  {:>4}  {:.5}", n, v);
            last = *v;
        }
    }
    println!(
        "\ncampaign: {completed} completed, {pruned} pruned in {:.0}s",
        wall.as_secs_f64()
    );
    println!("baseline (default hp): {baseline:.5}");
    println!("campaign best:         {best:.5}");
    println!(
        "improvement:           {:.1}% {}",
        100.0 * (baseline - best) / baseline,
        if best < baseline { "— outperforms the previous configuration (paper §4 claim)" } else { "" }
    );
    server.stop();
    Ok(())
}

//! Crash-injection suite: drive recovery through every kill-point of
//! the incremental compaction protocol (log rotation → per-shard
//! segment cuts → manifest commit → sealed-log GC), plus torn batch
//! tails, asserting at each point:
//!
//! * **acknowledged ⇒ durable** — every mutation acknowledged before
//!   the crash is present after recovery;
//! * **replay idempotence** — nothing is applied twice, whatever
//!   half-finished artifacts (orphan segments, un-GC'd logs, tmp files)
//!   the crash left behind.
//!
//! The harness is `testutil::crash::KillSwitch`: the store consults it
//! at named points, and when it fires the storage behaves like a dead
//! process — the current operation and all later ones fail.

use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::json::{parse, Value};
use hopaas::store::Storage;
use hopaas::testutil::crash::KillSwitch;
use hopaas::testutil::TempDir;
use std::collections::HashMap;

const N_SHARDS: usize = 4;

fn config() -> EngineConfig {
    EngineConfig { n_shards: N_SHARDS, ..Default::default() }
}

fn ask_body(study: &str) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "{study}",
        "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
        "direction": "minimize",
        "sampler": {{"name": "random"}}
    }}"#
    ))
    .unwrap()
}

/// Deterministic workload: 6 studies (spread over the 4 shards) × 4
/// told trials each. Returns every acknowledged `(trial_id, value)`.
fn run_workload(engine: &Engine) -> Vec<(u64, f64)> {
    let mut acked = Vec::new();
    for s in 0..6u64 {
        for i in 0..4u64 {
            let r = engine.ask(&ask_body(&format!("ci-{s}"))).unwrap();
            let v = (s * 10 + i) as f64;
            engine.tell(r.trial_id, v).unwrap();
            acked.push((r.trial_id, v));
        }
    }
    acked
}

/// All completed trials after recovery, keyed by trial id. Panics on a
/// duplicate id — the replay-idempotence half of the contract.
fn recovered_tells(engine: &Engine) -> HashMap<u64, f64> {
    let mut out = HashMap::new();
    for s in engine.studies_json().as_arr().unwrap() {
        let sid = s.get("id").as_u64().unwrap();
        for t in engine.trials_json(sid).unwrap().as_arr().unwrap() {
            let id = t.get("id").as_u64().unwrap();
            if let Some(v) = t.get("value").as_f64() {
                assert!(out.insert(id, v).is_none(), "trial {id} applied twice");
            }
        }
    }
    out
}

#[test]
fn every_compaction_kill_point_preserves_acknowledged_state() {
    // (point, skip): skip=k fires on the k+1-th time the point is hit,
    // which is how the mid-segment cases pick a specific shard.
    let kill_points: &[(&str, usize)] = &[
        ("rotate", 0),
        ("segment.write", 0),              // first shard, before the tmp write
        ("segment.sync", 1),               // second shard, tmp written, not fsynced
        ("segment.rename", 2),             // third shard, fsynced, not renamed
        ("segment.write", N_SHARDS - 1),   // last shard mid-cut
        ("manifest.write", 0),
        ("manifest.rename", 0),            // segments durable, manifest not committed
        ("gc", 0),                         // manifest committed, sealed logs remain
    ];
    for &(point, skip) in kill_points {
        let label = format!("{point}[{skip}]");
        let dir = TempDir::new(&format!("ci-{point}-{skip}"));
        let ks = KillSwitch::new();
        let storage =
            Storage::open_with_hook(dir.path(), Some(ks.arm_nth(point, skip).hook())).unwrap();
        let engine = Engine::open_with_storage(storage, config()).unwrap();
        let acked = run_workload(&engine);
        assert!(
            engine.compact().is_err(),
            "{label}: compaction must die at the kill-point"
        );
        assert!(ks.fired(), "{label}: workload never reached the kill-point");
        drop(engine); // "power comes back": reopen clean

        let engine = Engine::open(dir.path(), config()).unwrap();
        let recovered = recovered_tells(&engine);
        assert_eq!(
            recovered.len(),
            acked.len(),
            "{label}: completed-trial count diverged"
        );
        for (id, v) in &acked {
            assert_eq!(
                recovered.get(id),
                Some(v),
                "{label}: acknowledged tell for trial {id} lost"
            );
        }
        assert_eq!(engine.recovery_stats().seq_order_violations, 0, "{label}");

        // The recovered engine keeps serving, and a full compaction now
        // succeeds and round-trips once more.
        let r = engine.ask(&ask_body("ci-0")).unwrap();
        engine.tell(r.trial_id, 99.0).unwrap();
        engine.compact().unwrap();
        drop(engine);
        let engine = Engine::open(dir.path(), config()).unwrap();
        let recovered = recovered_tells(&engine);
        assert_eq!(recovered.len(), acked.len() + 1, "{label}: post-recovery tell lost");
        assert_eq!(recovered.get(&r.trial_id), Some(&99.0), "{label}");
    }
}

#[test]
fn kill_point_inside_second_compaction_respects_first_manifest() {
    // First compaction commits cleanly; the second dies before its
    // manifest. Recovery must fall back to the *first* manifest and the
    // epoch-1 + epoch-2 logs.
    let dir = TempDir::new("ci-second-compact");
    let ks = KillSwitch::new();
    let acked;
    let late;
    {
        let storage =
            Storage::open_with_hook(dir.path(), Some(ks.hook())).unwrap();
        let engine = Engine::open_with_storage(storage, config()).unwrap();
        acked = run_workload(&engine);
        engine.compact().unwrap(); // epoch 0 → 1, manifest #1
        let r = engine.ask(&ask_body("ci-1")).unwrap();
        engine.tell(r.trial_id, 123.0).unwrap();
        late = r.trial_id;
        ks.arm_nth("segment.rename", 1);
        assert!(engine.compact().is_err());
        assert!(ks.fired());
    }
    let engine = Engine::open(dir.path(), config()).unwrap();
    let recovered = recovered_tells(&engine);
    assert_eq!(recovered.len(), acked.len() + 1);
    for (id, v) in &acked {
        assert_eq!(recovered.get(id), Some(v));
    }
    assert_eq!(recovered.get(&late), Some(&123.0));
}

#[test]
fn torn_batch_tail_loses_only_the_unacknowledged_suffix() {
    let dir = TempDir::new("ci-torn");
    let acked;
    {
        let engine = Engine::open(dir.path(), config()).unwrap();
        acked = run_workload(&engine);
    }
    // A power cut mid-batch leaves a half-written frame at the tail.
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.path().join("wal.log"))
            .unwrap();
        f.write_all(&[0x13, 0x37, 0x00]).unwrap();
    }
    let engine = Engine::open(dir.path(), config()).unwrap();
    let recovered = recovered_tells(&engine);
    for (id, v) in &acked {
        assert_eq!(recovered.get(id), Some(v), "acknowledged tell {id} lost");
    }
    // The torn tail is surfaced to operators.
    let stats = engine.recovery_stats();
    assert_eq!(stats.truncated_records, 1);
    assert!(stats.truncated_bytes >= 3);
    let json = engine.stats_json();
    assert_eq!(json.get("wal_recovery").get("truncated_records").as_u64(), Some(1));
}

#[test]
fn kill_during_group_commit_never_loses_an_acknowledged_tell() {
    // The fsync of some mid-workload batch fails; the in-flight
    // mutation is NACKed (the engine returns 500), and everything
    // acknowledged before it survives recovery.
    let dir = TempDir::new("ci-sync");
    let ks = KillSwitch::new();
    let mut acked: Vec<(u64, f64)> = Vec::new();
    {
        let storage = Storage::open_with_hook(dir.path(), Some(ks.hook())).unwrap();
        let engine = Engine::open_with_storage(storage, config()).unwrap();
        // Each told trial costs 2–3 synced batches; die somewhere in the
        // middle of the workload.
        ks.arm_nth("sync", 17);
        let mut died = false;
        'outer: for s in 0..6u64 {
            for i in 0..4u64 {
                let r = match engine.ask(&ask_body(&format!("cs-{s}"))) {
                    Ok(r) => r,
                    Err(_) => {
                        died = true;
                        break 'outer;
                    }
                };
                let v = (s * 10 + i) as f64;
                match engine.tell(r.trial_id, v) {
                    Ok(_) => acked.push((r.trial_id, v)),
                    Err(_) => {
                        died = true;
                        break 'outer;
                    }
                }
            }
        }
        assert!(died, "kill-point never fired");
        assert!(ks.fired());
    }
    let engine = Engine::open(dir.path(), config()).unwrap();
    let recovered = recovered_tells(&engine);
    for (id, v) in &acked {
        assert_eq!(recovered.get(id), Some(v), "acknowledged tell {id} lost");
    }
    assert_eq!(engine.recovery_stats().seq_order_violations, 0);
}

//! Crash-injection suite: drive recovery through every kill-point of
//! the incremental compaction protocol (log rotation → per-shard
//! segment cuts → manifest commit → sealed-log GC), plus torn batch
//! tails, asserting at each point:
//!
//! * **acknowledged ⇒ durable** — every mutation acknowledged before
//!   the crash is present after recovery;
//! * **replay idempotence** — nothing is applied twice, whatever
//!   half-finished artifacts (orphan segments, un-GC'd logs, tmp files)
//!   the crash left behind.
//!
//! The harness is `testutil::crash::KillSwitch`: the store consults it
//! at named points, and when it fires the storage behaves like a dead
//! process — the current operation and all later ones fail.

use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::json::{parse, Value};
use hopaas::store::{ReplFetch, Storage};
use hopaas::testutil::crash::KillSwitch;
use hopaas::testutil::TempDir;
use std::collections::HashMap;

const N_SHARDS: usize = 4;

fn config() -> EngineConfig {
    EngineConfig { n_shards: N_SHARDS, ..Default::default() }
}

fn ask_body(study: &str) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "{study}",
        "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
        "direction": "minimize",
        "sampler": {{"name": "random"}}
    }}"#
    ))
    .unwrap()
}

/// Deterministic workload: 6 studies (spread over the 4 shards) × 4
/// told trials each. Returns every acknowledged `(trial_id, value)`.
fn run_workload(engine: &Engine) -> Vec<(u64, f64)> {
    let mut acked = Vec::new();
    for s in 0..6u64 {
        for i in 0..4u64 {
            let r = engine.ask(&ask_body(&format!("ci-{s}"))).unwrap();
            let v = (s * 10 + i) as f64;
            engine.tell(r.trial_id, v).unwrap();
            acked.push((r.trial_id, v));
        }
    }
    acked
}

/// All completed trials after recovery, keyed by trial id. Panics on a
/// duplicate id — the replay-idempotence half of the contract.
fn recovered_tells(engine: &Engine) -> HashMap<u64, f64> {
    let mut out = HashMap::new();
    for s in engine.studies_json().as_arr().unwrap() {
        let sid = s.get("id").as_u64().unwrap();
        for t in engine.trials_json(sid).unwrap().as_arr().unwrap() {
            let id = t.get("id").as_u64().unwrap();
            if let Some(v) = t.get("value").as_f64() {
                assert!(out.insert(id, v).is_none(), "trial {id} applied twice");
            }
        }
    }
    out
}

#[test]
fn every_compaction_kill_point_preserves_acknowledged_state() {
    // (point, skip): skip=k fires on the k+1-th time the point is hit,
    // which is how the mid-segment cases pick a specific shard.
    let kill_points: &[(&str, usize)] = &[
        ("rotate", 0),
        ("segment.write", 0),              // first shard, before the tmp write
        ("segment.sync", 1),               // second shard, tmp written, not fsynced
        ("segment.rename", 2),             // third shard, fsynced, not renamed
        ("segment.write", N_SHARDS - 1),   // last shard mid-cut
        ("manifest.write", 0),
        ("manifest.rename", 0),            // segments durable, manifest not committed
        ("gc", 0),                         // manifest committed, sealed logs remain
    ];
    for &(point, skip) in kill_points {
        let label = format!("{point}[{skip}]");
        let dir = TempDir::new(&format!("ci-{point}-{skip}"));
        let ks = KillSwitch::new();
        let storage =
            Storage::open_with_hook(dir.path(), Some(ks.arm_nth(point, skip).hook())).unwrap();
        let engine = Engine::open_with_storage(storage, config()).unwrap();
        let acked = run_workload(&engine);
        assert!(
            engine.compact().is_err(),
            "{label}: compaction must die at the kill-point"
        );
        assert!(ks.fired(), "{label}: workload never reached the kill-point");
        drop(engine); // "power comes back": reopen clean

        let engine = Engine::open(dir.path(), config()).unwrap();
        let recovered = recovered_tells(&engine);
        assert_eq!(
            recovered.len(),
            acked.len(),
            "{label}: completed-trial count diverged"
        );
        for (id, v) in &acked {
            assert_eq!(
                recovered.get(id),
                Some(v),
                "{label}: acknowledged tell for trial {id} lost"
            );
        }
        assert_eq!(engine.recovery_stats().seq_order_violations, 0, "{label}");

        // The recovered engine keeps serving, and a full compaction now
        // succeeds and round-trips once more.
        let r = engine.ask(&ask_body("ci-0")).unwrap();
        engine.tell(r.trial_id, 99.0).unwrap();
        engine.compact().unwrap();
        drop(engine);
        let engine = Engine::open(dir.path(), config()).unwrap();
        let recovered = recovered_tells(&engine);
        assert_eq!(recovered.len(), acked.len() + 1, "{label}: post-recovery tell lost");
        assert_eq!(recovered.get(&r.trial_id), Some(&99.0), "{label}");
    }
}

#[test]
fn concurrent_cut_kill_points_preserve_acknowledged_state() {
    // Same contract as the sequential sweep above, but with the
    // segment cuts fanned out on a 4-thread compaction pool: the
    // `segment.*` kill-points now fire on *pool* threads (the shared
    // killed flag then fails the WAL writer too), and `manifest.write`
    // fires in the window between the last segment rename and the
    // manifest rename — every segment durably in place, commit point
    // never reached, so recovery must fall back to the log alone.
    let kill_points: &[(&str, usize)] = &[
        ("segment.write", 0),            // one cut dies before its tmp write
        ("segment.sync", 1),             // another cut's tmp written, not fsynced
        ("segment.rename", 2),           // third rename attempt dies
        ("segment.rename", N_SHARDS - 1), // last rename attempt dies
        ("manifest.write", 0),           // all renames durable, manifest not
    ];
    for &(point, skip) in kill_points {
        let label = format!("pool:{point}[{skip}]");
        let dir = TempDir::new(&format!("ci-pool-{point}-{skip}"));
        let ks = KillSwitch::new();
        let storage =
            Storage::open_with_hook(dir.path(), Some(ks.arm_nth(point, skip).hook())).unwrap();
        let pool_config = EngineConfig { compact_threads: 4, ..config() };
        let engine = Engine::open_with_storage(storage, pool_config.clone()).unwrap();
        let acked = run_workload(&engine);
        assert!(
            engine.compact().is_err(),
            "{label}: compaction must die at the kill-point"
        );
        assert!(ks.fired(), "{label}: workload never reached the kill-point");
        drop(engine);

        let engine = Engine::open(dir.path(), pool_config.clone()).unwrap();
        let recovered = recovered_tells(&engine);
        assert_eq!(recovered.len(), acked.len(), "{label}: completed-trial count diverged");
        for (id, v) in &acked {
            assert_eq!(
                recovered.get(id),
                Some(v),
                "{label}: acknowledged tell for trial {id} lost"
            );
        }
        assert_eq!(engine.recovery_stats().seq_order_violations, 0, "{label}");

        // The recovered engine keeps serving; a full parallel
        // compaction now succeeds and round-trips once more.
        let r = engine.ask(&ask_body("ci-0")).unwrap();
        engine.tell(r.trial_id, 99.0).unwrap();
        engine.compact().unwrap();
        drop(engine);
        let engine = Engine::open(dir.path(), pool_config).unwrap();
        let recovered = recovered_tells(&engine);
        assert_eq!(recovered.len(), acked.len() + 1, "{label}: post-recovery tell lost");
        assert_eq!(recovered.get(&r.trial_id), Some(&99.0), "{label}");
    }
}

#[test]
fn commit_acks_flow_while_segments_are_cut() {
    // The ownership inversion's point: while pool threads cut
    // segments, the WAL writer must keep committing batches. A shard's
    // tell issued *during* the compaction (from another thread) must be
    // acknowledged and durable even if the compaction then dies between
    // the cuts and the manifest — the record landed in the new epoch's
    // log, which recovery replays in full when no new manifest
    // committed.
    let dir = TempDir::new("ci-acks-during-compact");
    let ks = KillSwitch::new();
    let acked;
    let during;
    {
        let storage = Storage::open_with_hook(dir.path(), Some(ks.hook())).unwrap();
        let pool_config = EngineConfig { compact_threads: 4, ..config() };
        let engine =
            std::sync::Arc::new(Engine::open_with_storage(storage, pool_config).unwrap());
        acked = run_workload(&engine);
        // Die at the manifest write: every segment cut completes, the
        // commit point is never reached.
        ks.arm_nth("manifest.write", 0);
        let worker = {
            let engine = engine.clone();
            std::thread::spawn(move || {
                // Commits racing with the concurrent cuts; every Ok ack
                // must survive the crashed compaction.
                let mut acked = Vec::new();
                for i in 0..20u64 {
                    let Ok(r) = engine.ask(&ask_body("ci-during")) else { break };
                    if engine.tell(r.trial_id, 1000.0 + i as f64).is_ok() {
                        acked.push((r.trial_id, 1000.0 + i as f64));
                    }
                }
                acked
            })
        };
        assert!(engine.compact().is_err(), "compaction must die at manifest.write");
        assert!(ks.fired());
        during = worker.join().unwrap();
    }
    let engine = Engine::open(dir.path(), config()).unwrap();
    let recovered = recovered_tells(&engine);
    for (id, v) in acked.iter().chain(&during) {
        assert_eq!(recovered.get(id), Some(v), "acknowledged tell {id} lost");
    }
}

#[test]
fn kill_point_inside_second_compaction_respects_first_manifest() {
    // First compaction commits cleanly; the second dies before its
    // manifest. Recovery must fall back to the *first* manifest and the
    // epoch-1 + epoch-2 logs.
    let dir = TempDir::new("ci-second-compact");
    let ks = KillSwitch::new();
    let acked;
    let late;
    {
        let storage =
            Storage::open_with_hook(dir.path(), Some(ks.hook())).unwrap();
        let engine = Engine::open_with_storage(storage, config()).unwrap();
        acked = run_workload(&engine);
        engine.compact().unwrap(); // epoch 0 → 1, manifest #1
        let r = engine.ask(&ask_body("ci-1")).unwrap();
        engine.tell(r.trial_id, 123.0).unwrap();
        late = r.trial_id;
        ks.arm_nth("segment.rename", 1);
        assert!(engine.compact().is_err());
        assert!(ks.fired());
    }
    let engine = Engine::open(dir.path(), config()).unwrap();
    let recovered = recovered_tells(&engine);
    assert_eq!(recovered.len(), acked.len() + 1);
    for (id, v) in &acked {
        assert_eq!(recovered.get(id), Some(v));
    }
    assert_eq!(recovered.get(&late), Some(&123.0));
}

#[test]
fn torn_batch_tail_loses_only_the_unacknowledged_suffix() {
    let dir = TempDir::new("ci-torn");
    let acked;
    {
        let engine = Engine::open(dir.path(), config()).unwrap();
        acked = run_workload(&engine);
    }
    // A power cut mid-batch leaves a half-written frame at the tail.
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.path().join("wal.log"))
            .unwrap();
        f.write_all(&[0x13, 0x37, 0x00]).unwrap();
    }
    let engine = Engine::open(dir.path(), config()).unwrap();
    let recovered = recovered_tells(&engine);
    for (id, v) in &acked {
        assert_eq!(recovered.get(id), Some(v), "acknowledged tell {id} lost");
    }
    // The torn tail is surfaced to operators.
    let stats = engine.recovery_stats();
    assert_eq!(stats.truncated_records, 1);
    assert!(stats.truncated_bytes >= 3);
    let json = engine.stats_json();
    assert_eq!(json.get("wal_recovery").get("truncated_records").as_u64(), Some(1));
}

#[test]
fn kill_point_inside_lease_requeue_is_exactly_once() {
    // A worker with three leased trials dies; the lease-expiry sweep
    // persists `worker_lost`, then one `trial_requeue` per trial — and
    // the storage is killed on the fsync of the *second* requeue.
    // After recovery the worker must still be lost, the one durable
    // requeue must not be applied twice, and the remaining trials must
    // be requeued by the next sweep: each of the three trials is
    // re-assigned exactly once, with its original id/number/params.
    fn ask_body_worker(study: &str, worker: u64) -> Value {
        let mut v = ask_body(study);
        if let Value::Obj(o) = &mut v {
            o.set("worker", worker);
        }
        v
    }
    let fleet_config = EngineConfig {
        n_shards: N_SHARDS,
        lease_timeout: Some(0.02),
        requeue_max: 5,
        ..Default::default()
    };
    let dir = TempDir::new("ci-lease-requeue");
    let ks = KillSwitch::new();
    let mut issued: Vec<(u64, u64, String)> = Vec::new();
    {
        let storage = Storage::open_with_hook(dir.path(), Some(ks.hook())).unwrap();
        let engine = Engine::open_with_storage(storage, fleet_config.clone()).unwrap();
        let (w1, _) = engine.register_worker("w1", "spot", "gpu").unwrap();
        for _ in 0..3 {
            let r = engine.ask(&ask_body_worker("lq", w1)).unwrap();
            issued.push((r.trial_id, r.trial_number, r.params.to_string()));
        }
        std::thread::sleep(std::time::Duration::from_millis(40));
        // Syncs after arming: worker_lost (skip 0), requeue #1 (skip 1),
        // requeue #2 (skip 2 → fires).
        ks.arm_nth("sync", 2);
        let handled = engine.expire_leases();
        assert!(ks.fired(), "workload never reached the kill-point");
        assert_eq!(handled, 1, "exactly one requeue became durable before the crash");
    }
    let engine = Engine::open(dir.path(), fleet_config).unwrap();
    // The worker is durably lost and still holds the two un-requeued
    // leases; the next sweep picks them up with no deadline wait.
    engine.expire_leases();
    let (w2, _) = engine.register_worker("w2", "spot", "gpu").unwrap();
    let mut got: Vec<(u64, u64, String)> = Vec::new();
    for _ in 0..3 {
        let q = engine.ask(&ask_body_worker("lq", w2)).unwrap();
        assert!(q.requeued, "expected a re-assigned trial");
        got.push((q.trial_id, q.trial_number, q.params.to_string()));
        engine.tell(q.trial_id, 1.0).unwrap();
    }
    got.sort();
    let mut want = issued.clone();
    want.sort();
    assert_eq!(got, want, "each lost trial re-assigned exactly once");
    // The fourth ask is fresh: the queue is empty and numbering
    // continues where the original handouts stopped.
    let f = engine.ask(&ask_body_worker("lq", w2)).unwrap();
    assert!(!f.requeued);
    assert_eq!(f.trial_number, 3);
}

#[test]
fn kill_point_during_tenant_bind_rebuilds_tenant_counters() {
    // A tenant-attributed ask dies on the fsync of its trial_new +
    // lease_bind batch. The ask is NACKed (500) and its admission slot
    // returned in memory — but the dead process cannot roll the
    // unsynced frames back off disk (rollback is itself a kill-point),
    // so recovery replays *both* binds. The contract under test: the
    // tenant ledger always equals the lease table exactly — the torn
    // ask's slot is either absent (frame lost) or fully present (frame
    // survived), never half-counted — and quota headroom is computed
    // from that exact ledger.
    fn ask_body_worker(study: &str, worker: u64) -> Value {
        let mut v = ask_body(study);
        if let Value::Obj(o) = &mut v {
            o.set("worker", worker);
        }
        v
    }
    let fleet_config = EngineConfig {
        n_shards: N_SHARDS,
        lease_timeout: Some(60.0),
        tenant_quota: 2,
        ..Default::default()
    };
    let dir = TempDir::new("ci-tenant-bind");
    let ks = KillSwitch::new();
    let first;
    {
        let storage = Storage::open_with_hook(dir.path(), Some(ks.hook())).unwrap();
        let engine = Engine::open_with_storage(storage, fleet_config.clone()).unwrap();
        let (w, _) = engine.register_worker("w1", "cloud", "gpu").unwrap();
        let r1 = engine
            .ask_as(&ask_body_worker("tb", w), Some("alice"))
            .unwrap();
        first = r1.trial_id;
        assert_eq!(engine.fleet().lock().sched.tenant_active("alice"), 1);
        // Next fsync dies: the second ask's batch is never acknowledged.
        ks.arm_nth("sync", 0);
        assert!(
            engine.ask_as(&ask_body_worker("tb", w), Some("alice")).is_err(),
            "ask must fail when its batch cannot be made durable"
        );
        assert!(ks.fired());
        // The failed admission was returned: no phantom slot in memory.
        assert_eq!(engine.fleet().lock().sched.tenant_active("alice"), 1);
    }
    let engine = Engine::open(dir.path(), fleet_config).unwrap();
    let alice_leases = {
        let fl = engine.fleet().lock();
        let alice_leases = fl
            .leases
            .iter()
            .filter(|(_, info)| info.tenant.as_deref() == Some("alice"))
            .count() as u32;
        assert!(
            (1..=2).contains(&alice_leases),
            "acknowledged bind must survive; torn bind may: {alice_leases}"
        );
        assert_eq!(
            fl.sched.tenant_active("alice"),
            alice_leases,
            "tenant ledger rebuilt exactly from the surviving leases"
        );
        alice_leases
    };
    // Quota 2: exactly the remaining headroom fits, then the denial
    // still names the tenant.
    let (w2, _) = engine.register_worker("w2", "cloud", "gpu").unwrap();
    for _ in alice_leases..2 {
        let r = engine.ask_as(&ask_body_worker("tb", w2), Some("alice")).unwrap();
        assert!(!r.requeued);
    }
    let err = engine
        .ask_as(&ask_body_worker("tb", w2), Some("alice"))
        .unwrap_err();
    assert!(err.to_string().contains("tenant 'alice'"), "{err}");
    // The surviving lease releases its slot on tell, reopening headroom.
    engine.tell(first, 1.0).unwrap();
    assert_eq!(engine.fleet().lock().sched.tenant_active("alice"), 1);
    let r = engine.ask_as(&ask_body_worker("tb", w2), Some("alice")).unwrap();
    assert!(!r.requeued);
}

#[test]
fn kill_during_group_commit_never_loses_an_acknowledged_tell() {
    // The fsync of some mid-workload batch fails; the in-flight
    // mutation is NACKed (the engine returns 500), and everything
    // acknowledged before it survives recovery.
    let dir = TempDir::new("ci-sync");
    let ks = KillSwitch::new();
    let mut acked: Vec<(u64, f64)> = Vec::new();
    {
        let storage = Storage::open_with_hook(dir.path(), Some(ks.hook())).unwrap();
        let engine = Engine::open_with_storage(storage, config()).unwrap();
        // Each told trial costs 2–3 synced batches; die somewhere in the
        // middle of the workload.
        ks.arm_nth("sync", 17);
        let mut died = false;
        'outer: for s in 0..6u64 {
            for i in 0..4u64 {
                let r = match engine.ask(&ask_body(&format!("cs-{s}"))) {
                    Ok(r) => r,
                    Err(_) => {
                        died = true;
                        break 'outer;
                    }
                };
                let v = (s * 10 + i) as f64;
                match engine.tell(r.trial_id, v) {
                    Ok(_) => acked.push((r.trial_id, v)),
                    Err(_) => {
                        died = true;
                        break 'outer;
                    }
                }
            }
        }
        assert!(died, "kill-point never fired");
        assert!(ks.fired());
    }
    let engine = Engine::open(dir.path(), config()).unwrap();
    let recovered = recovered_tells(&engine);
    for (id, v) in &acked {
        assert_eq!(recovered.get(id), Some(v), "acknowledged tell {id} lost");
    }
    assert_eq!(engine.recovery_stats().seq_order_violations, 0);
}

#[test]
fn repl_kill_points_promoted_follower_preserves_acknowledged_state() {
    // (point, skip): where in the per-batch replication hand-off the
    // primary dies. `repl.publish` = batch durable on disk but never
    // shipped (and NACKed); `repl.ack` = durable and shipped but the
    // senders never heard back; `repl.wake` = fully acknowledged, only
    // the parked-poller wakeup is lost. At every point, promoting a
    // caught-up follower must preserve each acknowledged tell, and the
    // follower's state must be a prefix of what the old primary's log
    // recovers ("shipped ⊆ durable": the publish sits behind the fsync).
    let kill_points: &[(&str, usize)] = &[
        ("repl.publish", 5),
        ("repl.publish", 17),
        ("repl.ack", 5),
        ("repl.ack", 17),
        ("repl.wake", 5),
        ("repl.wake", 17),
    ];
    for &(point, skip) in kill_points {
        let label = format!("{point}[{skip}]");
        let dir_p = TempDir::new(&format!("ci-repl-p-{point}-{skip}"));
        let dir_f = TempDir::new(&format!("ci-repl-f-{point}-{skip}"));
        let ks = KillSwitch::new();
        let storage =
            Storage::open_with_hook(dir_p.path(), Some(ks.arm_nth(point, skip).hook())).unwrap();
        let primary = Engine::open_with_storage(storage, config()).unwrap();
        let follower = Engine::open(
            dir_f.path(),
            EngineConfig { follower: true, n_shards: N_SHARDS, ..Default::default() },
        )
        .unwrap();
        assert!(!follower.is_writable(), "{label}: follower must start read-only");

        // Drive the workload until the kill-point downs the primary.
        let mut acked: Vec<(u64, f64)> = Vec::new();
        let mut died = false;
        'outer: for s in 0..6u64 {
            for i in 0..4u64 {
                let r = match primary.ask(&ask_body(&format!("cr-{s}"))) {
                    Ok(r) => r,
                    Err(_) => {
                        died = true;
                        break 'outer;
                    }
                };
                let v = (s * 10 + i) as f64;
                match primary.tell(r.trial_id, v) {
                    Ok(_) => acked.push((r.trial_id, v)),
                    Err(_) => {
                        died = true;
                        break 'outer;
                    }
                }
            }
        }
        assert!(died, "{label}: kill-point never fired");
        assert!(ks.fired(), "{label}");

        // Drain whatever the primary shipped before dying — the
        // synchronous equivalent of the follower's applier loop.
        let source = primary.repl_source().expect("primary exposes a replication log");
        loop {
            match source.fetch(follower.repl_next(), 4096) {
                ReplFetch::Batches { records, next: _, primary_next } => {
                    follower.apply_repl_batch(&records, primary_next).unwrap();
                }
                ReplFetch::UpToDate { next } => {
                    follower.apply_repl_batch(&[], next).unwrap();
                    break;
                }
                ReplFetch::TooOld { oldest } => {
                    panic!("{label}: follower fell out of the window (oldest {oldest})")
                }
            }
        }
        drop(primary); // the primary host is gone

        // Promote the caught-up follower: every acked tell must be there.
        follower
            .promote()
            .unwrap_or_else(|e| panic!("{label}: promote failed: {e}"));
        assert!(follower.is_writable(), "{label}: promote must flip writable");
        let on_follower = recovered_tells(&follower);
        for (id, v) in &acked {
            assert_eq!(
                on_follower.get(id),
                Some(v),
                "{label}: acknowledged tell for trial {id} lost on promoted follower"
            );
        }

        // "Power comes back" on the old primary (as a data autopsy): the
        // follower's state must be a prefix of what its log recovers —
        // the follower may lack durable-but-unshipped tails, never hold
        // records the primary's disk does not.
        let recovered = Engine::open(dir_p.path(), config()).unwrap();
        let on_primary = recovered_tells(&recovered);
        for (id, v) in &on_follower {
            assert_eq!(
                on_primary.get(id),
                Some(v),
                "{label}: follower holds trial {id} the recovered primary's log lacks"
            );
        }
        for (id, v) in &acked {
            assert_eq!(
                on_primary.get(id),
                Some(v),
                "{label}: acknowledged tell for trial {id} lost on the recovered primary"
            );
        }

        // The promoted follower serves fresh writes with durable acks.
        let r = follower.ask(&ask_body("cr-0")).unwrap();
        follower.tell(r.trial_id, 123.0).unwrap();
        assert_eq!(recovered_tells(&follower).get(&r.trial_id), Some(&123.0), "{label}");
    }
}

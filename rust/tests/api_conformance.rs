//! T1 — Table 1 API conformance over real HTTP.
//!
//! Verifies the exact public contract of the paper's Table 1: methods,
//! request paths, token auth in the path, body schemas, and the error
//! envelope, plus the web data APIs of §3.

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::http::Client;
use hopaas::json::{parse, Value};

fn server(auth: bool) -> HopaasServer {
    HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: auth, ..Default::default() },
    )
    .unwrap()
}

fn ask_body() -> Value {
    parse(
        r#"{
        "study_name": "conformance",
        "properties": {
            "lr": {"low": 1e-5, "high": 1e-1, "type": "loguniform"},
            "layers": {"low": 1, "high": 4, "type": "int"},
            "opt": ["adam", "rmsprop"]
        },
        "direction": "minimize",
        "sampler": {"name": "tpe"},
        "pruner": {"name": "median", "min_trials": 2},
        "node": "conformance-node"
    }"#,
    )
    .unwrap()
}

#[test]
fn table1_version_is_get() {
    let s = server(false);
    let mut c = Client::connect(s.addr()).unwrap();
    let r = c.get("/api/version").unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert!(v.get("version").as_str().is_some());
    // POST on version is 405.
    assert_eq!(c.post("/api/version", b"{}").unwrap().status, 405);
    s.stop();
}

#[test]
fn table1_ask_is_post_with_token_path() {
    let s = server(true);
    let tok = s.bootstrap_token.clone();
    let mut c = Client::connect(s.addr()).unwrap();
    // GET is 405 on a valid path shape.
    assert_eq!(c.get(&format!("/api/ask/{tok}")).unwrap().status, 405);
    // POST with valid token returns the paper's contract: trial id +
    // hyperparameters to test.
    let r = c.post_json(&format!("/api/ask/{tok}"), &ask_body()).unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert!(v.get("trial_id").as_u64().is_some());
    let params = v.get("params");
    let lr = params.get("lr").as_f64().unwrap();
    assert!((1e-5..=1e-1).contains(&lr));
    let layers = params.get("layers").as_i64().unwrap();
    assert!((1..=4).contains(&layers));
    let opt = params.get("opt").as_str().unwrap();
    assert!(opt == "adam" || opt == "rmsprop");
    s.stop();
}

#[test]
fn table1_tell_finalizes() {
    let s = server(true);
    let tok = s.bootstrap_token.clone();
    let mut c = Client::connect(s.addr()).unwrap();
    let ask = c
        .post_json(&format!("/api/ask/{tok}"), &ask_body())
        .unwrap()
        .json_body()
        .unwrap();
    let id = ask.get("trial_id").as_u64().unwrap();
    let mut body = Value::obj();
    body.set("trial_id", id).set("value", 0.25);
    let r = c
        .post_json(&format!("/api/tell/{tok}"), &Value::Obj(body))
        .unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert_eq!(v.get("state").as_str(), Some("completed"));
    assert_eq!(v.get("is_best").as_bool(), Some(true));
    s.stop();
}

#[test]
fn table1_should_prune_boolean_response() {
    let s = server(true);
    let tok = s.bootstrap_token.clone();
    let mut c = Client::connect(s.addr()).unwrap();
    // Build history of 2 completed trials so the median pruner engages.
    for _ in 0..2 {
        let ask = c
            .post_json(&format!("/api/ask/{tok}"), &ask_body())
            .unwrap()
            .json_body()
            .unwrap();
        let id = ask.get("trial_id").as_u64().unwrap();
        let mut rep = Value::obj();
        rep.set("trial_id", id).set("step", 1u64).set("value", 1.0);
        c.post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep))
            .unwrap();
        let mut body = Value::obj();
        body.set("trial_id", id).set("value", 1.0);
        c.post_json(&format!("/api/tell/{tok}"), &Value::Obj(body))
            .unwrap();
    }
    // A terrible trial must receive should_prune=true...
    let ask = c
        .post_json(&format!("/api/ask/{tok}"), &ask_body())
        .unwrap()
        .json_body()
        .unwrap();
    let id = ask.get("trial_id").as_u64().unwrap();
    let mut rep = Value::obj();
    rep.set("trial_id", id).set("step", 1u64).set("value", 50.0);
    let v = c
        .post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep))
        .unwrap()
        .json_body()
        .unwrap();
    assert_eq!(v.get("should_prune").as_bool(), Some(true));
    // ...and a good one should_prune=false.
    let ask = c
        .post_json(&format!("/api/ask/{tok}"), &ask_body())
        .unwrap()
        .json_body()
        .unwrap();
    let id = ask.get("trial_id").as_u64().unwrap();
    let mut rep = Value::obj();
    rep.set("trial_id", id).set("step", 1u64).set("value", 0.1);
    let v = c
        .post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep))
        .unwrap()
        .json_body()
        .unwrap();
    assert_eq!(v.get("should_prune").as_bool(), Some(false));
    s.stop();
}

#[test]
fn auth_all_three_apis_reject_bad_tokens() {
    let s = server(true);
    let mut c = Client::connect(s.addr()).unwrap();
    for path in ["/api/ask/bad", "/api/tell/bad", "/api/should_prune/bad"] {
        let r = c.post_json(path, &ask_body()).unwrap();
        assert_eq!(r.status, 401, "{path}");
        let v = r.json_body().unwrap();
        assert!(v.get("detail").as_str().is_some(), "error envelope");
    }
    s.stop();
}

#[test]
fn token_expiry_honored() {
    let s = server(true);
    let mut c = Client::connect(s.addr()).unwrap();
    // Issue a token that expires immediately.
    let mut req = Value::obj();
    req.set("user", "short").set("ttl", 0.0);
    let tok = c
        .post_json("/api/token", &Value::Obj(req))
        .unwrap()
        .json_body()
        .unwrap();
    let tok = tok.get("token").as_str().unwrap().to_string();
    std::thread::sleep(std::time::Duration::from_millis(30));
    let r = c.post_json(&format!("/api/ask/{tok}"), &ask_body()).unwrap();
    assert_eq!(r.status, 401);
    s.stop();
}

#[test]
fn same_definition_joins_same_study_different_definition_does_not() {
    let s = server(false);
    let mut c = Client::connect(s.addr()).unwrap();
    let a1 = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
    let a2 = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
    assert_eq!(
        a1.get("study_id").as_u64(),
        a2.get("study_id").as_u64(),
        "identical definitions → same study"
    );
    assert_eq!(a1.get("study_key").as_str(), a2.get("study_key").as_str());
    let mut other = ask_body();
    if let Value::Obj(o) = &mut other {
        o.set("direction", "maximize");
    }
    let a3 = c.post_json("/api/ask/x", &other).unwrap().json_body().unwrap();
    assert_ne!(a1.get("study_id").as_u64(), a3.get("study_id").as_u64());
    s.stop();
}

#[test]
fn concurrent_asks_get_unique_trials() {
    let s = server(false);
    let addr = s.addr();
    let handles: Vec<_> = (0..8)
        .map(|_| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                (0..10)
                    .map(|_| {
                        c.post_json("/api/ask/x", &ask_body())
                            .unwrap()
                            .json_body()
                            .unwrap()
                            .get("trial_id")
                            .as_u64()
                            .unwrap()
                    })
                    .collect::<Vec<u64>>()
            })
        })
        .collect();
    let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let n = all.len();
    all.sort();
    all.dedup();
    assert_eq!(all.len(), n, "all trial ids unique under concurrency");
    s.stop();
}

#[test]
fn pareto_endpoint_is_get_with_schema() {
    let s = server(false);
    let mut c = Client::connect(s.addr()).unwrap();
    // Unknown study → 404 with the error envelope.
    let r = c.get("/api/studies/99/pareto").unwrap();
    assert_eq!(r.status, 404);
    assert!(r.json_body().unwrap().get("detail").as_str().is_some());

    // A multi-objective study: ask twice, tell vector values.
    let mo_body = parse(
        r#"{
        "study_name": "pareto-conf",
        "properties": {"x": {"low": 0.0, "high": 1.0}},
        "direction": ["minimize", "minimize"],
        "sampler": {"name": "random"}
    }"#,
    )
    .unwrap();
    let mut sid = 0;
    let mut front_ids = Vec::new();
    for values in [[0.1, 0.9], [0.9, 0.1]] {
        let ask = c.post_json("/api/ask/x", &mo_body).unwrap().json_body().unwrap();
        sid = ask.get("study_id").as_u64().unwrap();
        let id = ask.get("trial_id").as_u64().unwrap();
        front_ids.push(id);
        let mut tell = Value::obj();
        tell.set("trial_id", id)
            .set("values", Value::Arr(values.iter().map(|&v| Value::Num(v)).collect()));
        let r = c.post_json("/api/tell/x", &Value::Obj(tell)).unwrap();
        assert_eq!(r.status, 200);
    }

    let r = c.get(&format!("/api/studies/{sid}/pareto")).unwrap();
    assert_eq!(r.status, 200);
    let front = r.json_body().unwrap();
    let arr = front.as_arr().unwrap();
    // Both points are mutually non-dominated → both on the front, each
    // with full trial schema (id, state, values).
    assert_eq!(arr.len(), 2);
    for t in arr {
        assert!(front_ids.contains(&t.get("id").as_u64().unwrap()));
        assert_eq!(t.get("state").as_str(), Some("completed"));
        assert_eq!(t.get("values").as_arr().unwrap().len(), 2);
    }
    // POST on the read endpoint is 405.
    assert_eq!(c.post(&format!("/api/studies/{sid}/pareto"), b"{}").unwrap().status, 405);
    // A single-objective study has an empty (but valid) front.
    let ask = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
    let so_sid = ask.get("study_id").as_u64().unwrap();
    let r = c.get(&format!("/api/studies/{so_sid}/pareto")).unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.json_body().unwrap().as_arr().unwrap().len(), 0);
    s.stop();
}

#[test]
fn engine_stats_api() {
    let s = server(false);
    let mut c = Client::connect(s.addr()).unwrap();
    c.post_json("/api/ask/x", &ask_body()).unwrap();
    let stats = c.get("/api/stats").unwrap().json_body().unwrap();
    assert_eq!(stats.get("shards").as_u64(), Some(8));
    assert_eq!(stats.get("studies").as_u64(), Some(1));
    assert_eq!(stats.get("asks").as_u64(), Some(1));
    assert_eq!(stats.get("tracked_running").as_u64(), Some(1));
    assert_eq!(stats.get("durable").as_bool(), Some(false));
    // Recovery block: always present, zeroed for an in-memory engine.
    let recovery = stats.get("wal_recovery");
    for key in [
        "recovered_records",
        "filtered_records",
        "truncated_records",
        "truncated_bytes",
        "segments",
        "orphan_records",
        "seq_order_violations",
    ] {
        assert_eq!(recovery.get(key).as_u64(), Some(0), "wal_recovery.{key}");
    }
    // The same surface is exported as Prometheus gauges.
    let m = c.get("/metrics").unwrap();
    let text = String::from_utf8(m.body).unwrap();
    assert!(text.contains("# TYPE hopaas_wal_recovered_records gauge"));
    assert!(text.contains("hopaas_wal_recovered_records 0"));
    assert!(text.contains("# TYPE hopaas_wal_truncated_records gauge"));
    assert!(text.contains("hopaas_wal_truncated_records 0"));
    assert!(text.contains("hopaas_wal_filtered_records 0"));
    s.stop();
}

#[test]
fn web_data_apis_schema() {
    let s = server(false);
    let mut c = Client::connect(s.addr()).unwrap();
    let ask = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
    let sid = ask.get("study_id").as_u64().unwrap();

    let study = c.get(&format!("/api/studies/{sid}")).unwrap().json_body().unwrap();
    for key in [
        "id", "key", "name", "direction", "sampler", "properties",
        "n_trials", "n_running", "n_completed", "n_pruned", "n_failed",
    ] {
        assert!(!study.get(key).is_null() || key == "best_value", "missing {key}");
    }
    let trials = c
        .get(&format!("/api/studies/{sid}/trials"))
        .unwrap()
        .json_body()
        .unwrap();
    let t = trials.at(0);
    assert_eq!(t.get("state").as_str(), Some("running"));
    assert_eq!(t.get("node").as_str(), Some("conformance-node"));
    // Prometheus metrics.
    let m = c.get("/metrics").unwrap();
    assert!(String::from_utf8(m.body).unwrap().contains("hopaas_ask_total"));
    s.stop();
}

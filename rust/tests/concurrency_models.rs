//! End-to-end tests for the deterministic interleaving checker
//! (`hopaas::testutil::sched`) over the concurrency protocol
//! miniatures (`hopaas::testutil::models`): the shipped protocols
//! survive exhaustive search, every planted bug is found and named,
//! a named interleaving replays to the identical failure, and the
//! whole exploration is deterministic in its seed.

use hopaas::testutil::models;
use hopaas::testutil::sched::{explore, replay, FailureKind, Options};

fn opts() -> Options {
    Options { max_execs: 4096, random_execs: 1024, seed: 0x5EED_CAFE, max_steps: 256 }
}

#[test]
fn shipped_protocols_survive_exhaustive_search() {
    for m in models::all(false) {
        let report = explore(&m.factory, &opts());
        assert!(
            report.failure.is_none(),
            "{}: shipped protocol failed: {:?}",
            m.name,
            report.failure
        );
        assert!(
            report.exhaustive,
            "{}: expected exhaustive coverage, got {} execs without finishing",
            m.name, report.execs
        );
        assert!(report.execs > 1, "{}: trivial exploration", m.name);
    }
}

#[test]
fn planted_bugs_are_found_and_named() {
    for m in models::all(true) {
        let report = explore(&m.factory, &opts());
        let failure = report
            .failure
            .unwrap_or_else(|| panic!("{}: planted bug not found in {} execs", m.name, report.execs));
        assert!(
            matches!(failure.kind, FailureKind::Invariant(_)),
            "{}: expected an invariant violation, got {:?}",
            m.name,
            failure.kind
        );
        // The failing interleaving is named after its decision string
        // and carries a non-trivial trace.
        assert!(failure.name.starts_with("ilv-"), "{}: {}", m.name, failure.name);
        assert!(!failure.choices.is_empty(), "{}: empty decision string", m.name);
        assert!(failure.trace.len() >= failure.choices.len().min(2));
    }
}

/// The PR-4 bug class: double slot release. The pre-fix logic (flag
/// check and slot decrement under separate lock acquisitions) must
/// reproduce as a failing interleaving; the shipped logic must not.
#[test]
fn pr4_double_slot_release_reproduces_against_prefix_logic() {
    let buggy = models::slot_release_once(true);
    let report = explore(&buggy.factory, &opts());
    let failure = report.failure.expect("double release not found");
    match &failure.kind {
        FailureKind::Invariant(msg) => {
            assert!(msg.contains("used = -1"), "unexpected invariant message: {msg}")
        }
        other => panic!("expected invariant violation, got {other:?}"),
    }
    // The trace names the two colliding release paths.
    let rendered = failure.render_trace();
    assert!(rendered.contains("reaper:release"), "trace:\n{rendered}");
    assert!(rendered.contains("fail:release"), "trace:\n{rendered}");

    let fixed = models::slot_release_once(false);
    let report = explore(&fixed.factory, &opts());
    assert!(report.failure.is_none(), "shipped slot release failed: {:?}", report.failure);
    assert!(report.exhaustive);
}

#[test]
fn named_interleaving_replays_to_identical_failure() {
    for m in models::all(true) {
        let found = explore(&m.factory, &opts()).failure.expect("bug not found");
        let replayed = replay(&m.factory, &found.choices, 256)
            .failure
            .unwrap_or_else(|| panic!("{}: replay of {} came back clean", m.name, found.name));
        assert_eq!(replayed.name, found.name, "{}", m.name);
        assert_eq!(replayed.kind, found.kind, "{}", m.name);
        assert_eq!(replayed.trace, found.trace, "{}", m.name);
        assert_eq!(replayed.choices, found.choices, "{}", m.name);
    }
}

#[test]
fn replaying_a_clean_interleaving_stays_clean() {
    // The all-zeros decision string on the shipped promote-once model
    // is a plain sequential run.
    let m = models::promote_once(false);
    let report = replay(&m.factory, &[0; 16], 256);
    assert!(report.failure.is_none(), "{:?}", report.failure);
}

#[test]
fn exploration_is_deterministic_in_its_options() {
    // DFS phase: two explorations of the same buggy model emit the
    // identical failure, trace and execution count.
    for m1 in models::all(true) {
        let m2 = models::all(true).into_iter().find(|m| m.name == m1.name).unwrap();
        let (a, b) = (explore(&m1.factory, &opts()), explore(&m2.factory, &opts()));
        assert_eq!(a.execs, b.execs, "{}", m1.name);
        let (fa, fb) = (a.failure.unwrap(), b.failure.unwrap());
        assert_eq!(fa.name, fb.name, "{}", m1.name);
        assert_eq!(fa.kind, fb.kind, "{}", m1.name);
        assert_eq!(fa.trace, fb.trace, "{}", m1.name);
    }

    // Seeded-random phase: strangle the DFS budget so discovery happens
    // in the random phase, and check the same seed tells the same story.
    let tight = Options { max_execs: 1, random_execs: 2048, seed: 42, max_steps: 256 };
    let run = |seed: u64| {
        let m = models::promote_once(true);
        let mut o = tight;
        o.seed = seed;
        explore(&m.factory, &o)
    };
    let (a, b) = (run(42), run(42));
    assert_eq!(a.execs, b.execs);
    let (fa, fb) = (a.failure.expect("found"), b.failure.expect("found"));
    assert_eq!(fa.name, fb.name);
    assert_eq!(fa.choices, fb.choices);
    assert_eq!(fa.trace, fb.trace);
}

#[test]
fn opposite_lock_orders_deadlock_and_are_reported() {
    let buggy = models::lock_order_demo(true);
    let report = explore(&buggy.factory, &opts());
    let failure = report.failure.expect("AB/BA deadlock not found");
    assert_eq!(failure.kind, FailureKind::Deadlock);
    // Replay reproduces the hang as a report, not an actual hang.
    let replayed = replay(&buggy.factory, &failure.choices, 256).failure.expect("replay");
    assert_eq!(replayed.kind, FailureKind::Deadlock);
    assert_eq!(replayed.trace, failure.trace);

    let fixed = models::lock_order_demo(false);
    let report = explore(&fixed.factory, &opts());
    assert!(report.failure.is_none(), "{:?}", report.failure);
    assert!(report.exhaustive);
}

//! E8 — end-to-end request tracing: a caller-chosen `X-Request-Id`
//! submitted on an `ask` is recoverable via `GET /api/trace/{id}` with a
//! per-stage timeline spanning admission, shard lock, sampler fit, the
//! WAL commit it joined (queue / shared fsync / ack) and the view
//! publish; every response echoes its request id; `/api/trace/recent`
//! filters by kind and study; and the `/metrics` scrape passes a
//! whole-scrape Prometheus exposition lint (HELP/TYPE ordering, label
//! escaping, bucket monotonicity and `+Inf` totals).

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::http::Client;
use hopaas::json::{parse, Value};
use std::collections::HashMap;

struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!("hopaas-it-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn durable_config(dir: &std::path::Path) -> HopaasConfig {
    HopaasConfig {
        auth_required: false,
        data_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

fn ask_body() -> Value {
    parse(
        r#"{
        "study_name": "traced",
        "properties": {"x": {"low": 0.0, "high": 1.0}},
        "sampler": {"name": "tpe"}
    }"#,
    )
    .unwrap()
}

fn tell_body(trial_id: u64, value: f64) -> Value {
    let mut o = Value::obj();
    o.set("trial_id", trial_id).set("value", value);
    Value::Obj(o)
}

#[test]
fn custom_request_id_recovers_full_stage_timeline() {
    let dir = TempDir::new("obs-trace");
    let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // Seed one completed trial first: sampler fits are cached per
    // tell-epoch, so a tell in between guarantees the traced ask below
    // performs (and therefore records) a fresh fit.
    let ask = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
    let tid = ask.get("trial_id").as_u64().unwrap();
    assert_eq!(c.post_json("/api/tell/x", &tell_body(tid, 1.0)).unwrap().status, 200);

    // The traced ask, with a caller-chosen id.
    let body = ask_body().to_string().into_bytes();
    let resp = c
        .request(
            "POST",
            "/api/ask/x",
            &[("content-type", "application/json"), ("x-request-id", "it-ask-0007")],
            Some(&body),
        )
        .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.headers.get("x-request-id"), Some("it-ask-0007"), "id not echoed");

    let r = c.get("/api/trace/it-ask-0007").unwrap();
    assert_eq!(r.status, 200);
    let trace = r.json_body().unwrap();
    assert_eq!(trace.get("id").as_str(), Some("it-ask-0007"));
    assert_eq!(trace.get("kind").as_str(), Some("ask"));
    assert_eq!(trace.get("status").as_u64(), Some(200));
    assert!(trace.get("total_us").as_u64().is_some());
    let stages: Vec<String> = trace
        .get("stages")
        .as_arr()
        .expect("full render carries the stage array")
        .iter()
        .map(|s| s.get("stage").as_str().unwrap().to_string())
        .collect();
    for want in
        ["admission", "shard_lock", "sampler_fit", "wal_queue", "wal_fsync", "wal_ack", "view_publish"]
    {
        assert!(stages.iter().any(|s| s == want), "stage {want} missing from {stages:?}");
    }

    // The WAL commit ledger attributes the batch to the same id.
    let stats = c.get("/api/stats").unwrap().json_body().unwrap();
    let batches = stats.get("wal_commit").get("recent_batches");
    let attributed = batches.as_arr().unwrap_or(&[]).iter().any(|b| {
        b.get("traces")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .any(|t| t.as_str() == Some("it-ask-0007"))
    });
    assert!(attributed, "traced ask not in wal_commit.recent_batches: {batches}");

    // Unknown or evicted ids are a clean 404.
    assert_eq!(c.get("/api/trace/no-such-id").unwrap().status, 404);
    server.stop();
}

#[test]
fn generated_ids_echo_and_recent_filters() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // No client id: the server mints one, echoes it, and the trace is
    // queryable under it.
    let r = c.get("/api/version").unwrap();
    let rid = r.headers.get("x-request-id").expect("generated id echoed").to_string();
    assert!(rid.starts_with("req-"), "{rid}");
    let tr = c.get(&format!("/api/trace/{rid}")).unwrap();
    assert_eq!(tr.status, 200);
    assert_eq!(tr.json_body().unwrap().get("kind").as_str(), Some("read"));

    // Populate the buffer with one ask and one tell.
    let ask = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
    let study_id = ask.get("study_id").as_u64().unwrap();
    let tid = ask.get("trial_id").as_u64().unwrap();
    assert_eq!(c.post_json("/api/tell/x", &tell_body(tid, 0.5)).unwrap().status, 200);

    // kind filter: only asks come back.
    let v = c.get("/api/trace/recent?limit=50&kind=ask").unwrap().json_body().unwrap();
    let traces = v.as_arr().expect("recent returns an array");
    assert!(!traces.is_empty());
    for t in traces {
        assert_eq!(t.get("kind").as_str(), Some("ask"), "{t}");
    }

    // study filter: every row belongs to the bench study.
    let v = c
        .get(&format!("/api/trace/recent?limit=50&study={study_id}"))
        .unwrap()
        .json_body()
        .unwrap();
    let traces = v.as_arr().unwrap();
    assert!(!traces.is_empty());
    for t in traces {
        assert_eq!(t.get("study").as_u64(), Some(study_id), "{t}");
    }

    // Unknown kind names are rejected, not silently ignored.
    assert_eq!(c.get("/api/trace/recent?kind=bogus").unwrap().status, 422);

    // /api/stats carries tracer counters, build info and uptime.
    let stats = c.get("/api/stats").unwrap().json_body().unwrap();
    assert_eq!(stats.get("trace").get("enabled").as_bool(), Some(true));
    assert!(stats.get("trace").get("retained").as_u64().unwrap() > 0);
    assert_eq!(stats.get("build").get("version").as_str(), Some(hopaas::VERSION));
    assert!(stats.get("uptime_seconds").as_f64().is_some());
    server.stop();
}

#[test]
fn metrics_scrape_is_prometheus_conformant() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // Exercise ask / prune / tell so the latency histograms have
    // samples (bucket monotonicity on empty families is vacuous).
    for i in 0..5 {
        let ask = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
        let tid = ask.get("trial_id").as_u64().unwrap();
        let mut rep = Value::obj();
        rep.set("trial_id", tid).set("step", 1u64).set("value", i as f64);
        assert_eq!(c.post_json("/api/should_prune/x", &Value::Obj(rep)).unwrap().status, 200);
        assert_eq!(c.post_json("/api/tell/x", &tell_body(tid, i as f64)).unwrap().status, 200);
    }

    let resp = c.get("/metrics").unwrap();
    assert_eq!(resp.status, 200);
    let text = String::from_utf8(resp.body).unwrap();
    assert!(text.contains("hopaas_build_info{"), "build info gauge missing");
    assert!(text.contains("hopaas_uptime_seconds"), "uptime gauge missing");
    assert!(text.contains("hopaas_slow_trace_seconds"), "exemplar family missing");
    lint_prometheus_scrape(&text);
    server.stop();
}

/// Whole-scrape Prometheus exposition lint: every family announces
/// `# HELP` immediately followed by `# TYPE` exactly once before any of
/// its samples; label values are well-formed (quoted, only `\\`, `\"`,
/// `\n` escapes); histogram buckets come in ascending `le` order with
/// non-decreasing cumulative counts, end at `+Inf`, and the `+Inf`
/// count equals the family's `_count`.
fn lint_prometheus_scrape(text: &str) {
    let mut typed: HashMap<String, String> = HashMap::new();
    let mut pending_help: Option<String> = None;
    // (family, non-le labels) -> [(le, cumulative count)] in line order.
    let mut buckets: HashMap<(String, String), Vec<(f64, f64)>> = HashMap::new();
    let mut counts: HashMap<(String, String), f64> = HashMap::new();

    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap().to_string();
            assert!(pending_help.is_none(), "HELP {name} follows a HELP with no TYPE");
            assert!(!typed.contains_key(&name), "duplicate family {name}");
            pending_help = Some(name);
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap().to_string();
            let ty = it.next().expect("TYPE line without a type").to_string();
            assert!(
                matches!(ty.as_str(), "counter" | "gauge" | "histogram" | "summary" | "untyped"),
                "unknown type {ty} for {name}"
            );
            assert_eq!(
                pending_help.take().as_deref(),
                Some(name.as_str()),
                "TYPE {name} not immediately preceded by its HELP"
            );
            typed.insert(name, ty);
            continue;
        }
        assert!(!line.starts_with('#'), "unexpected comment line: {line}");
        let (name, labels, value) = parse_sample(line);
        let family = family_of(&name, &typed);
        let ty = typed
            .get(&family)
            .unwrap_or_else(|| panic!("sample {name} before HELP/TYPE of {family}"));
        if ty == "histogram" {
            if name.ends_with("_bucket") {
                let le = &labels.iter().find(|(k, _)| k == "le").expect("bucket without le").1;
                let le = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap() };
                buckets.entry((family, labels_key(&labels))).or_default().push((le, value));
            } else if name.ends_with("_count") {
                counts.insert((family, labels_key(&labels)), value);
            }
        }
    }
    assert!(pending_help.is_none(), "dangling HELP without TYPE");

    for ((family, lk), seq) in &buckets {
        let mut last_le = f64::NEG_INFINITY;
        let mut last_count = -1.0;
        for (le, count) in seq {
            assert!(*le > last_le, "{family}{{{lk}}}: le not strictly ascending");
            assert!(*count >= last_count, "{family}{{{lk}}}: bucket counts not monotone");
            last_le = *le;
            last_count = *count;
        }
        assert!(last_le.is_infinite(), "{family}{{{lk}}}: missing +Inf bucket");
        let total = counts
            .get(&(family.clone(), lk.clone()))
            .unwrap_or_else(|| panic!("{family}{{{lk}}}: buckets but no _count"));
        assert_eq!(last_count, *total, "{family}{{{lk}}}: +Inf bucket != _count");
    }
}

/// Histogram/summary samples use suffixed names; map back to the family.
fn family_of(name: &str, typed: &HashMap<String, String>) -> String {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if typed.contains_key(base) {
                return base.to_string();
            }
        }
    }
    name.to_string()
}

/// Parse `name{labels} value` or `name value`.
fn parse_sample(line: &str) -> (String, Vec<(String, String)>, f64) {
    if let Some(brace) = line.find('{') {
        let name = line[..brace].to_string();
        let (labels, used) = parse_labels(&line[brace + 1..]);
        let value: f64 = line[brace + 1 + used..]
            .trim()
            .parse()
            .unwrap_or_else(|_| panic!("bad sample value: {line}"));
        (name, labels, value)
    } else {
        let mut it = line.split_whitespace();
        let name = it.next().unwrap().to_string();
        let value: f64 = it
            .next()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("bad sample line: {line}"));
        (name, Vec::new(), value)
    }
}

/// Parse `key="value",...}` starting just past the `{`; panics on any
/// exposition-format violation. Returns the labels and the number of
/// bytes consumed through the closing brace.
fn parse_labels(s: &str) -> (Vec<(String, String)>, usize) {
    let b = s.as_bytes();
    let mut i = 0;
    let mut labels = Vec::new();
    while b[i] != b'}' {
        let key_start = i;
        while b[i] != b'=' {
            i += 1;
        }
        let key = s[key_start..i].to_string();
        i += 1;
        assert_eq!(b[i], b'"', "unquoted label value in: {s}");
        i += 1;
        let mut val = Vec::new();
        while b[i] != b'"' {
            if b[i] == b'\\' {
                i += 1;
                assert!(
                    matches!(b[i], b'\\' | b'"' | b'n'),
                    "invalid escape \\{} in: {s}",
                    b[i] as char
                );
            }
            val.push(b[i]);
            i += 1;
        }
        i += 1;
        labels.push((key, String::from_utf8(val).unwrap()));
        if b[i] == b',' {
            i += 1;
        }
    }
    (labels, i + 1)
}

/// Stable key for a label set minus `le` (bucket grouping).
fn labels_key(labels: &[(String, String)]) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .filter(|(k, _)| k != "le")
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    parts.sort();
    parts.join(",")
}

//! E7 — fault tolerance: server crash/restart mid-campaign and node
//! churn. The paper's campaigns run for days on opportunistic resources;
//! the invariant is that every acknowledged mutation survives a restart
//! and silent nodes never wedge a study.

use hopaas::coordinator::engine::EngineConfig;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::json::{parse, Value};
use hopaas::objectives::Objective;
use hopaas::worker::{Campaign, HopaasClient, StudySpec};

struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!("hopaas-it-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn durable_config(dir: &std::path::Path) -> HopaasConfig {
    HopaasConfig {
        auth_required: false,
        data_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

#[test]
fn restart_preserves_all_told_trials() {
    let dir = TempDir::new("restart");
    let spec = StudySpec::new("restart-study")
        .uniform("x", 0.0, 1.0)
        .sampler("random");

    // Phase 1: run some trials, stop the server (simulated crash — the
    // WAL is not gracefully closed, which is exactly the point).
    let mut told: Vec<(u64, f64)> = Vec::new();
    let running_id;
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
        for i in 0..10 {
            let t = c.ask(&spec).unwrap();
            let v = i as f64 * 0.1;
            c.should_prune(&t, 1, v + 1.0).unwrap();
            c.tell(&t, v).unwrap();
            told.push((t.trial_id, v));
        }
        let t = c.ask(&spec).unwrap();
        running_id = t.trial_id;
        server.stop();
    }

    // Phase 2: a new server over the same storage sees everything.
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
        let studies = c.studies().unwrap();
        assert_eq!(studies.as_arr().unwrap().len(), 1);
        let sid = studies.at(0).get("id").as_u64().unwrap();
        assert_eq!(studies.at(0).get("n_completed").as_i64(), Some(10));
        assert_eq!(studies.at(0).get("n_running").as_i64(), Some(1));

        let trials = server.engine.trials_json(sid).unwrap();
        for (id, v) in &told {
            let t = trials
                .as_arr()
                .unwrap()
                .iter()
                .find(|t| t.get("id").as_u64() == Some(*id))
                .unwrap_or_else(|| panic!("trial {id} lost"));
            assert_eq!(t.get("state").as_str(), Some("completed"));
            assert_eq!(t.get("value").as_f64(), Some(*v));
        }
        // The still-running trial survived as running and can be told now.
        let t = hopaas::worker::TrialHandle {
            trial_id: running_id,
            trial_number: 10,
            study_id: sid,
            params: Value::Null,
            requeued: false,
            request_id: None,
        };
        c.tell(&t, 0.001).unwrap();
        // Best over {0.0, 0.1, ..., 0.9, 0.001} is still the told 0.0.
        assert_eq!(c.best_value(sid).unwrap(), Some(0.0));
        server.stop();
    }
}

#[test]
fn restart_after_compaction_preserves_state() {
    let dir = TempDir::new("compact");
    let spec = StudySpec::new("compact-study")
        .uniform("x", 0.0, 1.0)
        .sampler("random");
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
        for i in 0..5 {
            let t = c.ask(&spec).unwrap();
            c.tell(&t, i as f64).unwrap();
        }
        server.engine.compact().unwrap();
        // More events after the snapshot.
        let t = c.ask(&spec).unwrap();
        c.tell(&t, -5.0).unwrap();
        server.stop();
    }
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let studies = server.engine.studies_json();
        assert_eq!(studies.at(0).get("n_completed").as_i64(), Some(6));
        assert_eq!(studies.at(0).get("best_value").as_f64(), Some(-5.0));
        server.stop();
    }
}

#[test]
fn recovery_resumes_trial_id_sequence_without_collision() {
    let dir = TempDir::new("ids");
    let spec = StudySpec::new("ids").uniform("x", 0.0, 1.0).sampler("random");
    let max_id;
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
        max_id = (0..7).map(|_| c.ask(&spec).unwrap().trial_id).max().unwrap();
        server.stop();
    }
    let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
    let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
    let new_id = c.ask(&spec).unwrap().trial_id;
    assert!(new_id > max_id, "{new_id} must exceed {max_id}");
    server.stop();
}

#[test]
fn churny_campaign_under_durable_server_loses_nothing() {
    // A preemption-heavy fleet against a durable server, then restart and
    // compare completed counts.
    let dir = TempDir::new("churn");
    let completed;
    {
        let server = HopaasServer::start(
            "127.0.0.1:0",
            HopaasConfig {
                auth_required: false,
                data_dir: Some(dir.0.clone()),
                engine: EngineConfig { reap_after: Some(0.2), ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap();
        let mut campaign = Campaign::new(server.addr(), "x".into(), Objective::Ackley);
        campaign.n_nodes = 8;
        campaign.max_trials = 60;
        campaign.steps_per_trial = 6;
        campaign.step_cost_us = 100;
        let report = campaign.run().unwrap();
        completed = report.completed;
        assert!(report.preempted > 0 || report.completed > 0);
        // Let the reaper clean up silent preempted trials.
        std::thread::sleep(std::time::Duration::from_millis(300));
        server.engine.reap_stale();
        server.stop();
    }
    let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
    let studies = server.engine.studies_json();
    let recovered_completed = studies.at(0).get("n_completed").as_i64().unwrap();
    assert_eq!(recovered_completed as u64, completed, "no told trial lost");
    // No trial is stuck running after reaping + recovery replay of fails.
    let running = studies.at(0).get("n_running").as_i64().unwrap();
    assert!(running >= 0); // trials reaped before stop were persisted as failed
    server.stop();
}

#[test]
fn concurrent_told_trials_survive_restart_under_group_commit() {
    // Many clients tell concurrently, so the WAL writer actually batches
    // (several records per fsync); the invariant is unchanged — every
    // tell that returned 200 must be present after restart.
    let dir = TempDir::new("group-commit");
    let told: Vec<(u64, f64)>;
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..6u64)
            .map(|t| {
                std::thread::spawn(move || {
                    let spec = StudySpec::new(&format!("gc-{t}"))
                        .uniform("x", 0.0, 1.0)
                        .sampler("random");
                    let mut c = HopaasClient::connect(addr, "x".into()).unwrap();
                    let mut acked = Vec::new();
                    for i in 0..10u64 {
                        let tr = c.ask(&spec).unwrap();
                        let v = (t * 100 + i) as f64;
                        c.tell(&tr, v).unwrap();
                        acked.push((tr.trial_id, v));
                    }
                    acked
                })
            })
            .collect();
        told = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let stats = server.engine.stats_json();
        let commit = stats.get("wal_commit");
        // 6 studies × (1 study_new + 10 trial_new + 10 trial_tell).
        assert_eq!(commit.get("records").as_u64(), Some(126));
        assert!(commit.get("batches").as_u64().unwrap() >= 1);
        server.stop();
    }
    let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
    let studies = server.engine.studies_json();
    assert_eq!(studies.as_arr().unwrap().len(), 6);
    let mut recovered = std::collections::HashMap::new();
    for s in studies.as_arr().unwrap() {
        let sid = s.get("id").as_u64().unwrap();
        for t in server.engine.trials_json(sid).unwrap().as_arr().unwrap() {
            if let (Some(id), Some(v)) = (t.get("id").as_u64(), t.get("value").as_f64()) {
                recovered.insert(id, v);
            }
        }
    }
    for (id, v) in &told {
        assert_eq!(recovered.get(id), Some(v), "acknowledged tell {id} lost");
    }
    server.stop();
}

#[test]
fn wal_torn_tail_tolerated_on_restart() {
    let dir = TempDir::new("torn");
    let spec = StudySpec::new("torn").uniform("x", 0.0, 1.0).sampler("random");
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
        for _ in 0..4 {
            let t = c.ask(&spec).unwrap();
            c.tell(&t, 1.0).unwrap();
        }
        server.stop();
    }
    // Corrupt the WAL tail (simulate a crash mid-write).
    let wal = dir.0.join("wal.log");
    let mut bytes = std::fs::read(&wal).unwrap();
    let cut = bytes.len() - 3;
    bytes.truncate(cut);
    bytes.extend_from_slice(&[0xDE, 0xAD]);
    std::fs::write(&wal, &bytes).unwrap();

    let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
    let studies = server.engine.studies_json();
    // The torn record (the last tell) is lost; everything before survives.
    let completed = studies.at(0).get("n_completed").as_i64().unwrap();
    assert!(completed >= 3, "prefix preserved, got {completed}");
    // The truncation is surfaced to operators: /api/stats and the
    // recovery gauges both report the torn tail.
    let stats = server.engine.stats_json();
    let recovery = stats.get("wal_recovery");
    assert_eq!(recovery.get("truncated_records").as_u64(), Some(1));
    assert!(recovery.get("truncated_bytes").as_u64().unwrap() >= 2);
    assert!(recovery.get("recovered_records").as_u64().unwrap() >= 7);
    server.engine.refresh_storage_metrics();
    let text = server.engine.metrics.render();
    assert!(text.contains("hopaas_wal_truncated_records 1"));
    assert!(text.contains("hopaas_wal_recovered_records"));
    server.stop();
}

#[test]
fn engine_rejects_writes_on_unknown_trials_after_recovery() {
    let dir = TempDir::new("unknown");
    {
        let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
        let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
        let spec = StudySpec::new("u").uniform("x", 0.0, 1.0).sampler("random");
        let _ = c.ask(&spec).unwrap();
        server.stop();
    }
    let server = HopaasServer::start("127.0.0.1:0", durable_config(&dir.0)).unwrap();
    let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
    let ghost = hopaas::worker::TrialHandle {
        trial_id: 99_999,
        trial_number: 0,
        study_id: 1,
        params: parse("{}").unwrap(),
        requeued: false,
        request_id: None,
    };
    match c.tell(&ghost, 1.0) {
        Err(hopaas::worker::WorkerError::Api { status: 404, .. }) => {}
        other => panic!("expected 404, got {other:?}"),
    }
    server.stop();
}

//! Fleet stress suite — the acceptance surface of the fleet subsystem.
//!
//! * a multi-site campaign with forced worker preemption completes with
//!   **zero permanently lost trials**: every preempted trial comes back
//!   via lease expiry (`Engine::expire_leases`; `reap_stale` is never
//!   called) and is re-assigned to a surviving worker;
//! * per-site concurrency quotas are **never exceeded** (the scheduler
//!   records a per-site high-water mark, asserted against the quota);
//! * requeueing never perturbs the **deterministic suggestion stream**:
//!   trial numbers stay unique and contiguous, and every (number →
//!   params) pair matches a sequential, preemption-free engine;
//! * a property test drives random issue/tell/expire schedules and
//!   checks that a lost worker's trials are re-assigned **exactly
//!   once**, in creation order, with the stream intact.

use hopaas::coordinator::engine::{ApiError, Engine, EngineConfig};
use hopaas::json::{parse, Value};
use hopaas::rng::{mix, Rng};
use hopaas::testutil::prop;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn ask_body(study: &str) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "{study}",
        "properties": {{"x": {{"low": 0.0, "high": 1.0}},
                        "lr": {{"low": 1e-5, "high": 1e-1, "type": "loguniform"}}}},
        "direction": "minimize",
        "sampler": {{"name": "random"}}
    }}"#
    ))
    .unwrap()
}

fn ask_body_worker(study: &str, worker: u64) -> Value {
    let mut v = ask_body(study);
    if let Value::Obj(o) = &mut v {
        o.set("worker", worker);
    }
    v
}

const SITE_QUOTA: u32 = 3;
const TARGET_TRIALS: u64 = 60;
const STUDIES: [&str; 2] = ["fleet-a", "fleet-b"];

/// The flagship scenario: two campaigns across two sites, eight workers
/// with a 30% chance of vanishing (spot-instance style) after any ask,
/// a lease-expiry pump instead of a reaper, and hard assertions on
/// completeness, quota ceilings and suggestion determinism.
#[test]
fn preempted_multi_site_campaign_loses_nothing() {
    let config = EngineConfig {
        n_shards: 4,
        lease_timeout: Some(0.15),
        site_quota: SITE_QUOTA,
        requeue_max: 10_000,
        ..Default::default()
    };
    let engine = Arc::new(Engine::in_memory(config));
    // trial id → (study, number, params) for every trial ever issued.
    let issued: Arc<Mutex<HashMap<u64, (String, u64, String)>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let completed: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
    let started = Arc::new(AtomicU64::new(0));
    let preempt_events = Arc::new(AtomicU64::new(0));
    let stop_pump = Arc::new(AtomicBool::new(false));

    // Lease-expiry pump: the role the serve loop plays in production.
    // `reap_stale` is deliberately never called anywhere in this test.
    let pump = {
        let engine = engine.clone();
        let stop = stop_pump.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                engine.expire_leases();
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };

    let workers: Vec<_> = (0..8u64)
        .map(|wi| {
            let engine = engine.clone();
            let issued = issued.clone();
            let completed = completed.clone();
            let started = started.clone();
            let preempt_events = preempt_events.clone();
            std::thread::spawn(move || {
                let study = STUDIES[(wi % 2) as usize];
                let site = if wi < 4 { "spot" } else { "cloud" };
                let mut rng = Rng::new(mix(0xF1EE7, wi));
                let mut respawns = 0u64;
                let (mut wid, _) = engine
                    .register_worker(&format!("w{wi}"), site, "sim-gpu")
                    .unwrap();
                loop {
                    if started.load(Ordering::Relaxed) >= TARGET_TRIALS {
                        break;
                    }
                    // Keep the lease alive: this instance is healthy.
                    let _ = engine.worker_heartbeat(wid);
                    let t = match engine.ask(&ask_body_worker(study, wid)) {
                        Ok(t) => t,
                        Err(ApiError::Quota(_)) => {
                            std::thread::sleep(Duration::from_micros(500));
                            continue;
                        }
                        Err(ApiError::Conflict(_)) => {
                            // This instance was descheduled long enough
                            // for the pump to declare it lost. Its trial
                            // is already queued for someone else; carry
                            // on as a fresh instance.
                            respawns += 1;
                            let (nwid, _) = engine
                                .register_worker(&format!("w{wi}-l{respawns}"), site, "sim-gpu")
                                .unwrap();
                            wid = nwid;
                            continue;
                        }
                        Err(e) => panic!("ask failed: {e}"),
                    };
                    if t.requeued {
                        // Re-assigned trial: must have been issued before,
                        // with identical number and parameters.
                        let map = issued.lock().unwrap();
                        let (s0, n0, p0) = map.get(&t.trial_id).expect("requeued unknown trial");
                        assert_eq!(s0, study);
                        assert_eq!(*n0, t.trial_number, "requeue changed the trial number");
                        assert_eq!(p0, &t.params.to_string(), "requeue changed the params");
                    } else {
                        started.fetch_add(1, Ordering::Relaxed);
                        let prev = issued.lock().unwrap().insert(
                            t.trial_id,
                            (study.to_string(), t.trial_number, t.params.to_string()),
                        );
                        assert!(prev.is_none(), "trial {} issued twice", t.trial_id);
                    }
                    if rng.chance(0.3) {
                        // Preempted: the instance vanishes mid-trial — no
                        // tell, no fail, no deregister. A replacement
                        // instance registers and carries on.
                        preempt_events.fetch_add(1, Ordering::Relaxed);
                        respawns += 1;
                        let (nwid, _) = engine
                            .register_worker(&format!("w{wi}-r{respawns}"), site, "sim-gpu")
                            .unwrap();
                        wid = nwid;
                    } else {
                        // A straggler race is possible by design: if this
                        // worker's lease expired mid-trial, the trial may
                        // already be re-assigned and told by its new
                        // holder — then this tell 409s, which is fine.
                        if engine.tell(t.trial_id, t.trial_number as f64).is_ok() {
                            completed.lock().unwrap().insert(t.trial_id);
                        }
                    }
                }
            })
        })
        .collect();
    for h in workers {
        h.join().unwrap();
    }

    // Drain: let the abandoned leases expire, then hand every queued
    // trial to a dedicated drain worker until nothing is left.
    let (mut dw, _) = engine.register_worker("drain", "spot", "sim-gpu").unwrap();
    let mut spins = 0;
    loop {
        engine.expire_leases();
        if engine.worker_heartbeat(dw).is_err() {
            let (ndw, _) = engine.register_worker("drain-r", "spot", "sim-gpu").unwrap();
            dw = ndw;
        }
        if engine.fleet().lock().leases.queue_depth() > 0 {
            for study in STUDIES {
                loop {
                    let t = match engine.ask(&ask_body_worker(study, dw)) {
                        Ok(t) => t,
                        Err(ApiError::Quota(_)) | Err(ApiError::Conflict(_)) => break,
                        Err(e) => panic!("drain ask failed: {e}"),
                    };
                    if !t.requeued {
                        // Fresh trial (this study's queue is empty):
                        // record it, finish it, move on.
                        issued.lock().unwrap().insert(
                            t.trial_id,
                            (study.to_string(), t.trial_number, t.params.to_string()),
                        );
                        if engine.tell(t.trial_id, 0.5).is_ok() {
                            completed.lock().unwrap().insert(t.trial_id);
                        }
                        break;
                    }
                    if engine.tell(t.trial_id, 0.5).is_ok() {
                        completed.lock().unwrap().insert(t.trial_id);
                    }
                }
            }
        }
        let (depth, live) = {
            let fl = engine.fleet().lock();
            (fl.leases.queue_depth(), fl.leases.len())
        };
        if depth == 0 && live == 0 {
            break;
        }
        spins += 1;
        assert!(spins < 2000, "drain never converged: depth={depth} live={live}");
        std::thread::sleep(Duration::from_millis(5));
    }
    stop_pump.store(true, Ordering::Relaxed);
    pump.join().unwrap();

    // --- zero permanently lost trials ------------------------------------
    let issued = issued.lock().unwrap();
    let completed = completed.lock().unwrap();
    assert!(preempt_events.load(Ordering::Relaxed) > 0, "preemption never exercised");
    assert!(
        engine.metrics.fleet_trials_requeued.get() > 0,
        "no lease-expiry requeue happened"
    );
    for (tid, (study, number, _)) in issued.iter() {
        assert!(
            completed.contains(tid),
            "trial {tid} (study {study}, number {number}) was permanently lost"
        );
    }
    // Nothing still running, nothing failed, nothing queued.
    for sv in engine.studies_json().as_arr().unwrap() {
        assert_eq!(sv.get("n_running").as_i64(), Some(0), "{sv}");
        assert_eq!(sv.get("n_failed").as_i64(), Some(0), "{sv}");
    }

    // --- per-site quota never exceeded ------------------------------------
    let stats = engine.stats_json();
    let sites = stats.get("fleet").get("sites");
    let mut seen_sites = 0;
    for sv in sites.as_arr().unwrap() {
        seen_sites += 1;
        let peak = sv.get("peak").as_u64().unwrap();
        assert!(
            peak <= SITE_QUOTA as u64,
            "site {} peaked at {peak} > quota {SITE_QUOTA}",
            sv.get("site")
        );
    }
    assert_eq!(seen_sites, 2, "{stats}");

    // --- suggestion streams deterministic ---------------------------------
    // Numbers are unique and contiguous per study, and each (number →
    // params) pair matches a sequential engine that never saw a worker,
    // a lease or a preemption.
    for study in STUDIES {
        let mut by_number: Vec<(u64, String)> = issued
            .values()
            .filter(|(s, _, _)| s == study)
            .map(|(_, n, p)| (*n, p.clone()))
            .collect();
        by_number.sort();
        let numbers: Vec<u64> = by_number.iter().map(|(n, _)| *n).collect();
        assert_eq!(
            numbers,
            (0..by_number.len() as u64).collect::<Vec<_>>(),
            "study {study}: numbers not contiguous"
        );
        let clean = Engine::in_memory(EngineConfig::default());
        for (n, params) in &by_number {
            let c = clean.ask(&ask_body(study)).unwrap();
            assert_eq!(c.trial_number, *n);
            assert_eq!(
                &c.params.to_string(),
                params,
                "study {study} trial {n}: stream diverged from sequential run"
            );
        }
    }
}

/// Fair share under contention: a greedy campaign that filled a site
/// must yield slots to a newly arriving campaign as its trials finish.
#[test]
fn greedy_campaign_cannot_starve_a_site() {
    let config = EngineConfig {
        lease_timeout: Some(30.0),
        site_quota: 4,
        ..Default::default()
    };
    let e = Engine::in_memory(config);
    let (w, _) = e.register_worker("w", "gpu-site", "a100").unwrap();
    // Greedy campaign A fills the site.
    let mut a_trials = Vec::new();
    for _ in 0..4 {
        a_trials.push(e.ask(&ask_body_worker("greedy", w)).unwrap());
    }
    assert!(matches!(
        e.ask(&ask_body_worker("greedy", w)),
        Err(ApiError::Quota(_))
    ));
    // Campaign B arrives: denied while the site is full, but now marked
    // waiting.
    assert!(matches!(
        e.ask(&ask_body_worker("modest", w)),
        Err(ApiError::Quota(_))
    ));
    // One greedy trial finishes. Greedy asks first — and is refused in
    // favor of the waiter (fair share = ceil(4/2) = 2, greedy holds 3).
    e.tell(a_trials.pop().unwrap().trial_id, 1.0).unwrap();
    assert!(matches!(
        e.ask(&ask_body_worker("greedy", w)),
        Err(ApiError::Quota(_))
    ));
    let b = e.ask(&ask_body_worker("modest", w)).unwrap();
    assert!(!b.requeued);
    // The high-water mark never crossed the quota.
    let stats = e.stats_json();
    let peak = stats.get("fleet").get("sites").at(0).get("peak").as_u64().unwrap();
    assert!(peak <= 4, "{stats}");
    assert!(e.metrics.fleet_quota_denials.get() >= 3);
}

/// Property: whatever the issue/tell split, a lost worker's running
/// trials are requeued exactly once, re-assigned in creation order with
/// identical ids/numbers/params, and the study's suggestion stream is
/// indistinguishable from a preemption-free sequential engine.
#[test]
fn prop_lost_workers_trials_reassigned_exactly_once() {
    prop::check(10, |g| {
        let shards = *g.choose(&[1usize, 4]);
        let e = Engine::in_memory(EngineConfig {
            n_shards: shards,
            lease_timeout: Some(0.001),
            requeue_max: 10,
            ..Default::default()
        });
        let clean = Engine::in_memory(EngineConfig::default());
        let n_trials = g.usize(1, 6);
        let told = g.usize(0, n_trials);
        let (w1, _) = e.register_worker("w1", "site", "gpu").map_err(|e| e.to_string())?;
        let mut handles = Vec::new();
        for _ in 0..n_trials {
            handles.push(e.ask(&ask_body_worker("p", w1)).map_err(|e| e.to_string())?);
        }
        for (i, h) in handles.iter().take(told).enumerate() {
            e.tell(h.trial_id, i as f64).map_err(|e| e.to_string())?;
        }
        std::thread::sleep(Duration::from_millis(5));
        let expired = e.expire_leases();
        prop::assert_holds(
            expired == n_trials - told,
            format!("expired {expired}, expected {}", n_trials - told),
        )?;
        prop::assert_holds(e.expire_leases() == 0, "second expiry must be a no-op")?;
        let (w2, _) = e.register_worker("w2", "site", "gpu").map_err(|e| e.to_string())?;
        for h in handles.iter().skip(told) {
            let q = e.ask(&ask_body_worker("p", w2)).map_err(|e| e.to_string())?;
            prop::assert_holds(q.requeued, "expected a requeued trial")?;
            prop::assert_holds(
                q.trial_id == h.trial_id && q.trial_number == h.trial_number,
                format!("re-assignment out of order: got {} want {}", q.trial_id, h.trial_id),
            )?;
            prop::assert_holds(
                q.params.to_string() == h.params.to_string(),
                "requeue changed the params",
            )?;
            e.tell(q.trial_id, 0.0).map_err(|e| e.to_string())?;
        }
        // The next ask is fresh and continues the number sequence.
        let f = e.ask(&ask_body_worker("p", w2)).map_err(|e| e.to_string())?;
        prop::assert_holds(
            !f.requeued && f.trial_number == n_trials as u64,
            format!("fresh trial got number {}", f.trial_number),
        )?;
        // Stream identical to a worker-less sequential engine.
        for k in 0..=n_trials {
            let c = clean.ask(&ask_body("p")).map_err(|e| e.to_string())?;
            let want = if k < n_trials {
                handles[k].params.to_string()
            } else {
                f.params.to_string()
            };
            prop::assert_holds(
                c.params.to_string() == want,
                format!("stream diverged at trial {k}"),
            )?;
        }
        Ok(())
    });
}

/// Per-site quota overrides beat the uniform default, and denials name
/// the site they protect.
#[test]
fn per_site_override_beats_default_quota() {
    let mut site_quota_map = HashMap::new();
    site_quota_map.insert("marconi100".to_string(), 3u32);
    let e = Engine::in_memory(EngineConfig {
        lease_timeout: Some(30.0),
        site_quota: 1,
        site_quota_map,
        ..Default::default()
    });
    let (w_small, _) = e.register_worker("w1", "private", "gtx").unwrap();
    let (w_big, _) = e.register_worker("w2", "marconi100", "v100").unwrap();
    // Default site: one slot.
    e.ask(&ask_body_worker("q", w_small)).unwrap();
    let err = e.ask(&ask_body_worker("q", w_small)).unwrap_err();
    assert!(matches!(err, ApiError::Quota(_)));
    assert!(err.to_string().contains("site 'private'"), "{err}");
    // Overridden site: three slots, independent of the default.
    for _ in 0..3 {
        e.ask(&ask_body_worker("q", w_big)).unwrap();
    }
    let err = e.ask(&ask_body_worker("q", w_big)).unwrap_err();
    assert!(err.to_string().contains("site 'marconi100'"), "{err}");
    // The stats block reports the resolved quota per site.
    let stats = e.stats_json();
    for sv in stats.get("fleet").get("sites").as_arr().unwrap() {
        let want = match sv.get("site").as_str().unwrap() {
            "marconi100" => 3,
            _ => 1,
        };
        assert_eq!(sv.get("quota").as_u64(), Some(want), "{sv}");
    }
}

/// Per-tenant quotas: 429s carry the tenant, counters follow leases
/// across tell/requeue, and recovery (log replay *and* compaction
/// segments) rebuilds the tenant ledger exactly as live admission
/// counted it.
#[test]
fn tenant_quota_survives_recovery_with_attribution() {
    use hopaas::testutil::TempDir;
    let d = TempDir::new("fleet-tenant-recovery");
    let config = EngineConfig { tenant_quota: 2, ..Default::default() };
    let first_trial;
    {
        let e = Engine::open(d.path(), config.clone()).unwrap();
        let (w, _) = e.register_worker("w1", "cloud", "gpu").unwrap();
        let r1 = e.ask_as(&ask_body_worker("tq", w), Some("alice")).unwrap();
        first_trial = r1.trial_id;
        e.ask_as(&ask_body_worker("tq", w), Some("alice")).unwrap();
        // Budget of two spent: the third ask names the tenant.
        let err = e.ask_as(&ask_body_worker("tq", w), Some("alice")).unwrap_err();
        assert!(matches!(err, ApiError::Quota(_)));
        assert!(err.to_string().contains("tenant 'alice'"), "{err}");
        // Another tenant is unaffected (and releases on tell).
        let rb = e.ask_as(&ask_body_worker("tq", w), Some("bob")).unwrap();
        e.tell(rb.trial_id, 1.0).unwrap();
        assert_eq!(e.fleet().lock().sched.tenant_active("bob"), 0);
    }
    // Reopen from the log: the two live leases rebuild alice's ledger.
    {
        let e = Engine::open(d.path(), config.clone()).unwrap();
        assert_eq!(e.fleet().lock().sched.tenant_active("alice"), 2);
        let (w2, _) = e.register_worker("w2", "cloud", "gpu").unwrap();
        let err = e.ask_as(&ask_body_worker("tq", w2), Some("alice")).unwrap_err();
        assert!(err.to_string().contains("tenant 'alice'"), "{err}");
        // Compact so the fleet segment (not the log) carries the leases.
        e.compact().unwrap();
    }
    // Reopen from the segment: same ledger, and headroom returns once a
    // lease is released.
    let e = Engine::open(d.path(), config).unwrap();
    assert_eq!(e.recovery_stats().recovered_records, 0, "state came from segments");
    assert_eq!(e.fleet().lock().sched.tenant_active("alice"), 2);
    let (w3, _) = e.register_worker("w3", "cloud", "gpu").unwrap();
    assert!(e.ask_as(&ask_body_worker("tq", w3), Some("alice")).is_err());
    e.tell(first_trial, 0.5).unwrap();
    assert_eq!(e.fleet().lock().sched.tenant_active("alice"), 1);
    let r = e.ask_as(&ask_body_worker("tq", w3), Some("alice")).unwrap();
    assert!(!r.requeued);
}

/// Site affinity: a site bleeding workers is deferred when a requeued
/// trial waits — the healthier site gets it, with the trial's id,
/// number and params untouched — and the suggestion stream stays
/// byte-identical to a sequential engine (the acceptance criterion for
/// affinity on vs. off).
#[test]
fn affinity_requeue_prefers_healthy_site_and_preserves_identity() {
    let config = EngineConfig {
        lease_timeout: Some(0.01),
        site_affinity: true,
        fairness_horizon: 60.0,
        ..Default::default()
    };
    let e = Engine::in_memory(config);
    let mut issued: Vec<(u64, u64, String)> = Vec::new();
    // A stable site does one clean trial (healthy ledger entry).
    let (w_stable, _) = e.register_worker("st1", "stable", "gpu").unwrap();
    let r = e.ask(&ask_body_worker("aff", w_stable)).unwrap();
    issued.push((r.trial_id, r.trial_number, r.params.to_string()));
    e.tell(r.trial_id, 0.1).unwrap();
    // A spot worker takes a trial and vanishes: spot's loss rate rises
    // above the fleet mean.
    let (w_spot, _) = e.register_worker("sp1", "spot", "gpu").unwrap();
    let lost = e.ask(&ask_body_worker("aff", w_spot)).unwrap();
    issued.push((lost.trial_id, lost.trial_number, lost.params.to_string()));
    std::thread::sleep(Duration::from_millis(30));
    // Both workers' deadlines passed during the sleep; only the spot
    // worker held a lease, so exactly one trial is requeued. The site
    // health ledger outlives the workers.
    assert_eq!(e.expire_leases(), 1, "spot worker lost, trial requeued");
    // A replacement spot worker asks: the queued trial is *deferred*
    // (held for a healthier site) and the worker gets a fresh trial.
    let (w_spot2, _) = e.register_worker("sp2", "spot", "gpu").unwrap();
    let fresh = e.ask(&ask_body_worker("aff", w_spot2)).unwrap();
    assert!(!fresh.requeued, "unhealthy site deferred within the grace window");
    issued.push((fresh.trial_id, fresh.trial_number, fresh.params.to_string()));
    assert!(e.metrics.fleet_affinity_deferrals.get() >= 1);
    assert_eq!(e.fleet().lock().leases.queue_depth(), 1, "trial still waiting");
    // A stable-site worker takes it: identical id, number and params.
    let (w_stable2, _) = e.register_worker("st2", "stable", "gpu").unwrap();
    let q = e.ask(&ask_body_worker("aff", w_stable2)).unwrap();
    assert!(q.requeued, "healthy site is served the queued trial");
    assert_eq!(
        (q.trial_id, q.trial_number, q.params.to_string()),
        (lost.trial_id, lost.trial_number, lost.params.to_string())
    );
    // Suggestion stream byte-identical to a sequential, affinity-free,
    // preemption-free engine.
    let clean = Engine::in_memory(EngineConfig::default());
    issued.sort_by_key(|(_, n, _)| *n);
    for (_, n, params) in &issued {
        let c = clean.ask(&ask_body("aff")).unwrap();
        assert_eq!(c.trial_number, *n);
        assert_eq!(&c.params.to_string(), params, "stream diverged at {n}");
    }
}

/// Affinity is a preference, not a starvation: once the queue head has
/// waited out the fairness horizon, even an unhealthy site takes it.
#[test]
fn affinity_grace_prevents_starvation() {
    let e = Engine::in_memory(EngineConfig {
        lease_timeout: Some(0.01),
        site_affinity: true,
        // The serve path clamps the horizon to ≥ 1 s; the engine takes
        // it as-is, which keeps this test fast.
        fairness_horizon: 0.05,
        ..Default::default()
    });
    let (w_stable, _) = e.register_worker("st1", "stable", "gpu").unwrap();
    let ok = e.ask(&ask_body_worker("g", w_stable)).unwrap();
    e.tell(ok.trial_id, 0.1).unwrap();
    let (w_spot, _) = e.register_worker("sp1", "spot", "gpu").unwrap();
    let lost = e.ask(&ask_body_worker("g", w_spot)).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    assert_eq!(e.expire_leases(), 1);
    // Wait out the grace, then the unhealthy site is allowed the trial.
    std::thread::sleep(Duration::from_millis(80));
    let (w_spot2, _) = e.register_worker("sp2", "spot", "gpu").unwrap();
    let q = e.ask(&ask_body_worker("g", w_spot2)).unwrap();
    assert!(q.requeued, "grace expired: no starvation");
    assert_eq!(q.trial_id, lost.trial_id);
    e.tell(q.trial_id, 1.0).unwrap();
}

/// Requeued trials survive a server restart: the queue itself is
/// durable (journaled `trial_requeue` records + the fleet segment).
#[test]
fn requeue_queue_survives_restart() {
    use hopaas::testutil::TempDir;
    let d = TempDir::new("fleet-requeue-restart");
    let issued;
    {
        let e = Engine::open(
            d.path(),
            EngineConfig { lease_timeout: Some(0.01), ..Default::default() },
        )
        .unwrap();
        let (w1, _) = e.register_worker("w1", "spot", "gpu").unwrap();
        let r = e.ask(&ask_body_worker("rq", w1)).unwrap();
        issued = (r.trial_id, r.trial_number, r.params.to_string());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(e.expire_leases(), 1);
        assert_eq!(e.fleet().lock().leases.queue_depth(), 1);
    }
    let e = Engine::open(
        d.path(),
        EngineConfig { lease_timeout: Some(60.0), ..Default::default() },
    )
    .unwrap();
    assert_eq!(e.fleet().lock().leases.queue_depth(), 1, "queue lost in recovery");
    let (w2, _) = e.register_worker("w2", "spot", "gpu").unwrap();
    let q = e.ask(&ask_body_worker("rq", w2)).unwrap();
    assert!(q.requeued);
    assert_eq!((q.trial_id, q.trial_number, q.params.to_string()), issued);
    e.tell(q.trial_id, 1.0).unwrap();
}

/// Regression (affinity amnesia): the site health ledger is persisted
/// in the fleet segment and rebuilt from replayed fleet records, so a
/// restarted server defers requeued trials away from a historically
/// lossy site exactly as the pre-restart ledger would — instead of
/// resetting to "everyone is healthy" and handing the queue head right
/// back to the spot pool that keeps dropping it.
#[test]
fn site_health_ledger_survives_restart_and_drives_affinity() {
    use hopaas::testutil::TempDir;
    let d = TempDir::new("fleet-health-restart");
    let config = EngineConfig {
        lease_timeout: Some(0.01),
        site_affinity: true,
        fairness_horizon: 60.0,
        ..Default::default()
    };
    {
        let e = Engine::open(d.path(), config.clone()).unwrap();
        // Stable site: one clean trial. Spot: takes one and vanishes.
        let (w_stable, _) = e.register_worker("st1", "stable", "gpu").unwrap();
        let ok = e.ask(&ask_body_worker("hl", w_stable)).unwrap();
        e.tell(ok.trial_id, 0.1).unwrap();
        let (w_spot, _) = e.register_worker("sp1", "spot", "gpu").unwrap();
        let lost = e.ask(&ask_body_worker("hl", w_spot)).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(e.expire_leases(), 1, "spot trial requeued");
        // Drain the queue before the restart — a stable worker finishes
        // the trial — so afterwards only the *ledger* remembers spot's
        // record, not a leftover queue entry.
        let (w_stable2, _) = e.register_worker("st2", "stable", "gpu").unwrap();
        let q = e.ask(&ask_body_worker("hl", w_stable2)).unwrap();
        assert!(q.requeued);
        assert_eq!(q.trial_id, lost.trial_id);
        e.tell(q.trial_id, 0.2).unwrap();
        assert!(!e.fleet().lock().sched.site_preferred("spot"));
        // Cut the fleet segment (ledger included) and "power-cycle".
        e.compact().unwrap();
    }
    let e = Engine::open(d.path(), config).unwrap();
    {
        let fl = e.fleet().lock();
        assert!(!fl.sched.site_preferred("spot"), "ledger reset to blank on restart");
        assert!(fl.sched.site_preferred("stable"));
    }
    // A fresh preemption after the restart: the persisted ledger must
    // shape the handout exactly as the pre-restart one would — the spot
    // replacement is deferred (fresh trial), the stable worker gets the
    // requeued trial with its identity intact.
    let (w_spot2, _) = e.register_worker("sp2", "spot", "gpu").unwrap();
    let lost2 = e.ask(&ask_body_worker("hl", w_spot2)).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    assert!(e.expire_leases() >= 1, "post-restart preemption requeued");
    // The stats block reports the merged (persisted + post-restart)
    // ledger: spot handed 2 / lost 2 across the restart.
    {
        let stats = e.stats_json();
        let sites = stats.get("fleet").get("sites");
        let spot = sites
            .as_arr()
            .unwrap()
            .iter()
            .find(|s| s.get("site").as_str() == Some("spot"))
            .expect("spot site reported");
        assert_eq!(spot.get("handed").as_u64(), Some(2));
        assert_eq!(spot.get("lost").as_u64(), Some(2));
    }
    let (w_spot3, _) = e.register_worker("sp3", "spot", "gpu").unwrap();
    let fresh = e.ask(&ask_body_worker("hl", w_spot3)).unwrap();
    assert!(!fresh.requeued, "persisted lossy ledger defers the spot site");
    assert!(e.metrics.fleet_affinity_deferrals.get() >= 1);
    let (w_stable3, _) = e.register_worker("st3", "stable", "gpu").unwrap();
    let q2 = e.ask(&ask_body_worker("hl", w_stable3)).unwrap();
    assert!(q2.requeued, "healthy site serves the queue head");
    assert_eq!(
        (q2.trial_id, q2.trial_number, q2.params.to_string()),
        (lost2.trial_id, lost2.trial_number, lost2.params.to_string())
    );
}

/// Regression (quota bypass): a worker-less (legacy) ask never holds a
/// lease, so tenant lease-quotas cannot bound it — the sliding
/// ask-rate ledger must.
#[test]
fn worker_less_asks_rate_limited_per_tenant() {
    let e = Engine::in_memory(EngineConfig {
        tenant_ask_rate: 3,
        tenant_ask_window: 3600.0,
        // A lease quota alone must NOT stop worker-less asks (that is
        // the bypass): prove the ledger is what denies.
        tenant_quota: 1,
        ..Default::default()
    });
    for _ in 0..3 {
        e.ask_as(&ask_body("wl"), Some("alice")).unwrap();
    }
    let err = e.ask_as(&ask_body("wl"), Some("alice")).unwrap_err();
    assert!(matches!(err, ApiError::Quota(_)), "{err}");
    assert!(err.to_string().contains("tenant 'alice'"), "{err}");
    assert!(err.to_string().contains("ask rate"), "{err}");
    assert_eq!(
        e.metrics.tenant_denials.lock().unwrap().get("alice").copied(),
        Some(1),
        "denial attributed to the tenant"
    );
    // Another tenant has its own window; tenant-less asks are unbounded.
    e.ask_as(&ask_body("wl"), Some("bob")).unwrap();
    for _ in 0..8 {
        e.ask_as(&ask_body("wl"), None).unwrap();
    }
}

//! Stress coverage for the sharded engine: concurrent `ask`/`tell`/
//! `should_prune` across many studies and threads, determinism of the
//! per-study suggestion streams under that concurrency (including many
//! threads hammering a *single* study), recovery after a simulated
//! crash mid-commit-batch, and byte-identical replay of old-format
//! (pre-manifest) on-disk state.

use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::json::{parse, Value};
use hopaas::testutil::TempDir;
use std::sync::Arc;

fn ask_body(study: &str, sampler: &str) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "{study}",
        "properties": {{
            "x": {{"low": 0.0, "high": 1.0}},
            "y": {{"low": 1e-4, "high": 1.0, "type": "loguniform"}}
        }},
        "direction": "minimize",
        "sampler": {{"name": "{sampler}"}}
    }}"#
    ))
    .unwrap()
}

const N_THREADS: usize = 8;
const N_STUDIES: usize = 12;

/// Per-thread trial count. `HOPAAS_TEST_SHORT=1` (set by the nightly
/// ThreadSanitizer CI job, where every operation costs 5-15x) trims the
/// workload without changing its shape.
fn trials_per_thread() -> usize {
    if std::env::var_os("HOPAAS_TEST_SHORT").is_some() { 8 } else { 30 }
}

/// Deterministic objective so concurrent and sequential runs feed the
/// samplers identical histories.
fn objective(study: usize, number: u64) -> f64 {
    ((study as f64 + 1.0) * 0.37 + number as f64 * 0.11).sin().abs()
}

#[test]
fn concurrent_mixed_workload_keeps_invariants() {
    let engine = Arc::new(Engine::in_memory(EngineConfig::default()));
    // Each thread interleaves work on its own study, a second study it
    // shares with a neighbor, and the common hot study — so shard locks
    // see genuine cross-thread traffic.
    let handles: Vec<_> = (0..N_THREADS)
        .map(|t| {
            let engine = engine.clone();
            std::thread::spawn(move || {
                let own = ask_body(&format!("stress-{t}"), "random");
                let shared = ask_body(&format!("stress-{}", (t + 1) % N_STUDIES), "random");
                let hot = ask_body("stress-hot", "random");
                for i in 0..trials_per_thread() {
                    for body in [&own, &shared, &hot] {
                        let r = engine.ask(body).unwrap();
                        if i % 3 == 0 {
                            let p = engine.should_prune(r.trial_id, 1, 0.5).unwrap();
                            if p {
                                continue;
                            }
                        }
                        engine.tell(r.trial_id, 0.5).unwrap();
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Global trial-id uniqueness and per-study number contiguity.
    let studies = engine.studies_json();
    let mut all_ids: Vec<u64> = Vec::new();
    for s in studies.as_arr().unwrap() {
        let sid = s.get("id").as_u64().unwrap();
        let trials = engine.trials_json(sid).unwrap();
        let mut numbers: Vec<u64> = trials
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.get("number").as_u64().unwrap())
            .collect();
        all_ids.extend(
            trials
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.get("id").as_u64().unwrap()),
        );
        numbers.sort_unstable();
        let expect: Vec<u64> = (0..numbers.len() as u64).collect();
        assert_eq!(numbers, expect, "study {sid}: trial numbers not contiguous");
    }
    let total = N_THREADS * trials_per_thread() * 3;
    assert_eq!(all_ids.len(), total);
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(all_ids.len(), total, "trial ids must be globally unique");
    // Every trial reached a terminal state → reap tracking is empty.
    assert_eq!(engine.tracked_running(), 0, "last_seen leaked entries");
}

#[test]
fn per_study_streams_deterministic_under_concurrency() {
    // One thread per study, model-based sampler (TPE) so history feeds
    // back into suggestions: the concurrent engine must produce, per
    // study, exactly the stream a sequential engine produces.
    let concurrent = Arc::new(Engine::in_memory(EngineConfig::default()));
    let handles: Vec<_> = (0..N_THREADS)
        .map(|t| {
            let engine = concurrent.clone();
            std::thread::spawn(move || {
                let body = ask_body(&format!("det-{t}"), "tpe");
                let mut stream = Vec::new();
                for _ in 0..20 {
                    let r = engine.ask(&body).unwrap();
                    stream.push(r.params.to_string());
                    engine.tell(r.trial_id, objective(t, r.trial_number)).unwrap();
                }
                (t, stream)
            })
        })
        .collect();
    let mut streams: Vec<(usize, Vec<String>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    streams.sort_by_key(|(t, _)| *t);

    // Sequential reference with the same seed (and a different shard
    // count, which must not matter).
    let reference = Engine::in_memory(EngineConfig { n_shards: 1, ..Default::default() });
    for (t, stream) in &streams {
        let body = ask_body(&format!("det-{t}"), "tpe");
        for (i, expect) in stream.iter().enumerate() {
            let r = reference.ask(&body).unwrap();
            assert_eq!(
                &r.params.to_string(),
                expect,
                "study det-{t} trial {i}: stream diverged"
            );
            reference.tell(r.trial_id, objective(*t, r.trial_number)).unwrap();
        }
    }
}

#[test]
fn same_study_concurrent_asks_match_sequential_stream() {
    // The seed engine's documented race: two asks on the same study
    // could sample with the same trial number and draw byte-identical
    // "random" suggestions. Numbers are now reserved under the shard
    // lock before sampling, so N threads hammering one study produce
    // exactly the suggestion stream of a sequential run.
    let engine = Arc::new(Engine::in_memory(EngineConfig::default()));
    let handles: Vec<_> = (0..N_THREADS)
        .map(|_| {
            let engine = engine.clone();
            std::thread::spawn(move || {
                let body = ask_body("same-study-hot", "random");
                let mut drawn = Vec::new();
                for _ in 0..25 {
                    let r = engine.ask(&body).unwrap();
                    drawn.push((r.trial_number, r.params.to_string()));
                    engine.tell(r.trial_id, 0.5).unwrap();
                }
                drawn
            })
        })
        .collect();
    let mut drawn: Vec<(u64, String)> =
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    drawn.sort();
    let total = (N_THREADS * 25) as u64;
    let numbers: Vec<u64> = drawn.iter().map(|(n, _)| *n).collect();
    assert_eq!(
        numbers,
        (0..total).collect::<Vec<u64>>(),
        "trial numbers must be unique and contiguous"
    );

    let reference = Engine::in_memory(EngineConfig { n_shards: 1, ..Default::default() });
    let body = ask_body("same-study-hot", "random");
    for (number, params) in &drawn {
        let r = reference.ask(&body).unwrap();
        assert_eq!(r.trial_number, *number);
        assert_eq!(
            &r.params.to_string(),
            params,
            "trial {number}: concurrent stream diverged from sequential"
        );
        reference.tell(r.trial_id, 0.5).unwrap();
    }
}

#[test]
fn old_format_snapshot_and_wal_replay_identically() {
    // A data directory written in the PR-1 format — a single full-state
    // `snapshot.json` plus one `wal.log`, no manifest — must replay on
    // the new engine to exactly the state an equivalent new-format
    // history produces, and continue the suggestion stream byte-for-
    // byte. Fixture: 1 study, trials 0–1 in the snapshot, trial 2 in
    // the log.
    use hopaas::coordinator::study::parse_ask_body;
    use hopaas::coordinator::trial::Trial;
    use hopaas::store::{Record, Wal};

    let body = ask_body("v1-compat", "random");
    let values = [0.25, 0.75, 0.5];

    // Reference: the same logical history executed natively.
    let reference_dir = TempDir::new("v1-reference");
    {
        let e = Engine::open(reference_dir.path(), EngineConfig::default()).unwrap();
        for v in values {
            let r = e.ask(&body).unwrap();
            e.tell(r.trial_id, v).unwrap();
        }
    }
    let reference = Engine::open(reference_dir.path(), EngineConfig::default()).unwrap();

    // Fixture: the identical history laid out as PR-1 files. Trial
    // params must match what the deterministic sampler drew, so pull
    // them from the reference engine's recovered state.
    let (def, _) = parse_ask_body(&body).unwrap();
    let ref_sid = reference.studies_json().at(0).get("id").as_u64().unwrap();
    let ref_trials = reference.trials_json(ref_sid).unwrap();
    let fixture_dir = TempDir::new("v1-fixture");
    {
        let mut snap_trials = Vec::new();
        for t in &ref_trials.as_arr().unwrap()[..2] {
            snap_trials.push(Trial::from_json(t).unwrap().to_json());
        }
        let mut study = Value::obj();
        study
            .set("id", 1u64)
            .set("def", def.canonical_json())
            .set("created_at", 0.0)
            .set("trials", Value::Arr(snap_trials));
        let mut snap = Value::obj();
        snap.set("studies", Value::Arr(vec![Value::Obj(study)]))
            .set("next_trial_id", 3u64);
        std::fs::write(
            fixture_dir.path().join("snapshot.json"),
            Value::Obj(snap).to_string(),
        )
        .unwrap();

        // The log carries trial 2 (id 3) as the engine would have
        // framed it after the snapshot cut.
        let third = Trial::from_json(ref_trials.at(2)).unwrap();
        let mut new_ev = Value::obj();
        new_ev
            .set("study_id", 1u64)
            .set("trial", Trial::new(3, 2, third.params.clone(), 0.0, None).to_json());
        let mut tell_ev = Value::obj();
        tell_ev.set("trial_id", 3u64).set("value", values[2]).set("at", 1.0);
        let mut wal = Wal::open(fixture_dir.path().join("wal.log")).unwrap();
        let mut rec0 = Record::new("trial_new", Value::Obj(new_ev));
        rec0.seq = 0;
        let mut rec1 = Record::new("trial_tell", Value::Obj(tell_ev));
        rec1.seq = 1;
        wal.append(&rec0.to_value()).unwrap();
        wal.append(&rec1.to_value()).unwrap();
    }

    // The old-format directory replays on the new engine...
    let e = Engine::open(fixture_dir.path(), EngineConfig::default()).unwrap();
    assert_eq!(e.n_studies(), 1);
    let sid = e.studies_json().at(0).get("id").as_u64().unwrap();
    let trials = e.trials_json(sid).unwrap();
    assert_eq!(trials.as_arr().unwrap().len(), 3);
    for (i, t) in trials.as_arr().unwrap().iter().enumerate() {
        assert_eq!(t.get("state").as_str(), Some("completed"), "trial {i}");
        assert_eq!(t.get("value").as_f64(), Some(values[i]), "trial {i}");
        assert_eq!(
            t.get("params").to_string(),
            ref_trials.at(i).get("params").to_string(),
            "trial {i} params"
        );
    }
    // ...and continues the stream byte-identically with the reference.
    let a = e.ask(&body).unwrap();
    let b = reference.ask(&body).unwrap();
    assert_eq!(a.trial_number, 3);
    assert_eq!(b.trial_number, 3);
    assert_eq!(a.params.to_string(), b.params.to_string());

    // Compacting migrates the directory to format v2 in place.
    e.compact().unwrap();
    assert!(fixture_dir.path().join("MANIFEST.json").exists());
    assert!(!fixture_dir.path().join("snapshot.json").exists());
    drop(e);
    let e = Engine::open(fixture_dir.path(), EngineConfig::default()).unwrap();
    assert_eq!(e.trials_json(sid).unwrap().as_arr().unwrap().len(), 4);
}

#[test]
fn crash_mid_batch_recovers_every_acknowledged_mutation() {
    let dir = TempDir::new("crash");
    // Phase 1: concurrent durable traffic; remember what was
    // acknowledged.
    let mut acknowledged: Vec<(u64, f64)> = Vec::new();
    {
        let engine = Arc::new(Engine::open(dir.path(), EngineConfig::default()).unwrap());
        let handles: Vec<_> = (0..N_THREADS)
            .map(|t| {
                let engine = engine.clone();
                std::thread::spawn(move || {
                    let body = ask_body(&format!("crash-{t}"), "random");
                    let mut acked = Vec::new();
                    for i in 0..15 {
                        let r = engine.ask(&body).unwrap();
                        let v = t as f64 + i as f64 * 0.01;
                        engine.tell(r.trial_id, v).unwrap();
                        // tell returned ⇒ the record's batch was fsynced.
                        acked.push((r.trial_id, v));
                    }
                    acked
                })
            })
            .collect();
        for h in handles {
            acknowledged.extend(h.join().unwrap());
        }
        // Commit batching happened (at least once the writer saw more
        // than one queued record) — and never broke durability below.
        let stats = engine.stats_json();
        assert!(stats.get("wal_commit").get("batches").as_u64().unwrap() >= 1);
        // Engine dropped here: the WAL writer drains and stops. The
        // acknowledged records were durable *before* each tell returned.
    }

    // Simulate the crash: a torn, half-written frame at the WAL tail
    // (what a power cut mid-batch leaves behind). No acknowledged bytes
    // are touched.
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.path().join("wal.log"))
            .unwrap();
        f.write_all(&[0x13, 0x37, 0x00]).unwrap();
    }

    // Phase 2: recovery sees every acknowledged tell, on a different
    // shard layout for good measure.
    let engine = Engine::open(dir.path(), EngineConfig { n_shards: 3, ..Default::default() }).unwrap();
    assert_eq!(engine.n_studies(), N_THREADS);
    let mut recovered: std::collections::HashMap<u64, f64> = std::collections::HashMap::new();
    let studies = engine.studies_json();
    for s in studies.as_arr().unwrap() {
        let sid = s.get("id").as_u64().unwrap();
        let trials = engine.trials_json(sid).unwrap();
        for t in trials.as_arr().unwrap() {
            if t.get("state").as_str() == Some("completed") {
                recovered.insert(
                    t.get("id").as_u64().unwrap(),
                    t.get("value").as_f64().unwrap(),
                );
            }
        }
    }
    for (id, v) in &acknowledged {
        assert_eq!(
            recovered.get(id),
            Some(v),
            "acknowledged tell for trial {id} lost in crash"
        );
    }
    // The recovered engine keeps serving without id collisions.
    let r = engine.ask(&ask_body("crash-0", "random")).unwrap();
    assert!(!recovered.contains_key(&r.trial_id));
}

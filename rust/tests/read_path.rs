//! Read-path conformance over real HTTP: cursor pagination against the
//! epoch-stamped materialized views, the `/best` incumbent probe, the
//! long-poll `/events` trial feed (fast path, park/wake, timeout), a
//! fixed-seed pagination fuzz, and the no-starvation guarantee for
//! parked long-poll readers on a small worker pool.

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::http::{Client, ServerConfig};
use hopaas::json::{parse, Value};
use hopaas::rng::Rng;
use std::time::{Duration, Instant};

fn server() -> HopaasServer {
    HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap()
}

fn ask_body(name: &str) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "{name}",
        "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
        "direction": "minimize",
        "sampler": {{"name": "random"}}
    }}"#,
    ))
    .unwrap()
}

/// Ask one trial, returning (study_id, trial_id).
fn ask(c: &mut Client, name: &str) -> (u64, u64) {
    let v = c.post_json("/api/ask/x", &ask_body(name)).unwrap().json_body().unwrap();
    (v.get("study_id").as_u64().unwrap(), v.get("trial_id").as_u64().unwrap())
}

fn tell(c: &mut Client, trial_id: u64, value: f64) {
    let mut b = Value::obj();
    b.set("trial_id", trial_id).set("value", value);
    let r = c.post_json("/api/tell/x", &Value::Obj(b)).unwrap();
    assert_eq!(r.status, 200);
}

/// Ids of a study's trials in slot order, via the legacy bare-array API.
fn legacy_trial_ids(c: &mut Client, sid: u64) -> Vec<u64> {
    let v = c.get(&format!("/api/studies/{sid}/trials")).unwrap().json_body().unwrap();
    v.as_arr().unwrap().iter().map(|t| t.get("id").as_u64().unwrap()).collect()
}

/// Cursor-walk a study's trials with a fixed page limit; returns the
/// concatenated ids and asserts every page is well-formed.
fn walk_trials(c: &mut Client, sid: u64, limit: usize) -> Vec<u64> {
    let mut ids = Vec::new();
    let mut path = format!("/api/studies/{sid}/trials?limit={limit}");
    loop {
        let r = c.get(&path).unwrap();
        assert_eq!(r.status, 200);
        let page = r.json_body().unwrap();
        let trials = page.get("trials").as_arr().unwrap();
        assert!(trials.len() <= limit, "page exceeds limit");
        ids.extend(trials.iter().map(|t| t.get("id").as_u64().unwrap()));
        match page.get("next_cursor").as_str() {
            Some(cur) => path = format!("/api/studies/{sid}/trials?limit={limit}&cursor={cur}"),
            None => return ids,
        }
    }
}

#[test]
fn studies_pagination_envelope_and_cursor_walk() {
    let s = server();
    let mut c = Client::connect(s.addr()).unwrap();
    let mut sids = Vec::new();
    for i in 0..5 {
        let (sid, tid) = ask(&mut c, &format!("page-{i}"));
        tell(&mut c, tid, i as f64);
        sids.push(sid);
    }
    sids.sort_unstable();

    // Paged list: envelope with total, summaries ordered by id, and the
    // last-id cursor chaining to the remainder.
    let p1 = c.get("/api/studies?limit=2").unwrap().json_body().unwrap();
    assert_eq!(p1.get("total").as_u64(), Some(5));
    let first = p1.get("studies").as_arr().unwrap();
    assert_eq!(first.len(), 2);
    for key in ["id", "name", "epoch", "n_trials", "n_completed", "best_value"] {
        assert!(!first[0].get(key).is_null() || key == "best_value", "summary missing {key}");
    }
    let mut got: Vec<u64> = first.iter().map(|v| v.get("id").as_u64().unwrap()).collect();
    let mut cursor = p1.get("next_cursor").as_str().map(str::to_string);
    while let Some(cur) = cursor {
        let p = c
            .get(&format!("/api/studies?limit=2&cursor={cur}"))
            .unwrap()
            .json_body()
            .unwrap();
        got.extend(p.get("studies").as_arr().unwrap().iter().map(|v| v.get("id").as_u64().unwrap()));
        cursor = p.get("next_cursor").as_str().map(str::to_string);
    }
    assert_eq!(got, sids, "paged study ids = full ordered set");
    // Malformed study cursor is a 422 with the error envelope.
    let r = c.get("/api/studies?limit=2&cursor=banana").unwrap();
    assert_eq!(r.status, 422);
    assert!(r.json_body().unwrap().get("detail").as_str().is_some());
    s.stop();
}

#[test]
fn trial_pages_cover_exactly_the_view_in_slot_order() {
    let s = server();
    let mut c = Client::connect(s.addr()).unwrap();
    let mut sid = 0;
    for i in 0..23 {
        let (study, tid) = ask(&mut c, "walk");
        sid = study;
        if i % 3 != 0 {
            tell(&mut c, tid, i as f64);
        }
    }
    let want = legacy_trial_ids(&mut c, sid);
    assert_eq!(want.len(), 23);
    for limit in [1, 4, 7, 23, 100] {
        assert_eq!(walk_trials(&mut c, sid, limit), want, "limit={limit}");
    }
    // State filter: pages contain only matching trials and their union
    // matches the summary's count.
    let summary = c.get(&format!("/api/studies/{sid}")).unwrap().json_body().unwrap();
    let n_completed = summary.get("n_completed").as_u64().unwrap() as usize;
    let mut seen = 0usize;
    let mut path = format!("/api/studies/{sid}/trials?limit=5&state=completed");
    loop {
        let page = c.get(&path).unwrap().json_body().unwrap();
        let trials = page.get("trials").as_arr().unwrap();
        for t in trials {
            assert_eq!(t.get("state").as_str(), Some("completed"));
        }
        seen += trials.len();
        match page.get("next_cursor").as_str() {
            Some(cur) => {
                path = format!("/api/studies/{sid}/trials?limit=5&state=completed&cursor={cur}")
            }
            None => break,
        }
    }
    assert_eq!(seen, n_completed, "filtered pages cover all completed trials");
    // Bad parameters are rejected with 422.
    for bad in [
        "limit=0",
        "limit=-3",
        "limit=abc",
        "limit=5&state=flying",
        "limit=5&cursor=v2.0.0",
        "limit=5&cursor=v1.9",
        "limit=5&cursor=v1.a.b",
        "limit=5&cursor=",
    ] {
        let r = c.get(&format!("/api/studies/{sid}/trials?{bad}")).unwrap();
        assert_eq!(r.status, 422, "{bad}");
        assert!(r.json_body().unwrap().get("detail").as_str().is_some(), "{bad}");
    }
    s.stop();
}

#[test]
fn legacy_bare_array_shapes_preserved_without_params() {
    let s = server();
    let mut c = Client::connect(s.addr()).unwrap();
    let (sid, tid) = ask(&mut c, "legacy");
    tell(&mut c, tid, 1.0);
    let studies = c.get("/api/studies").unwrap().json_body().unwrap();
    assert!(matches!(studies, Value::Arr(_)), "paramless /api/studies stays a bare array");
    let trials = c.get(&format!("/api/studies/{sid}/trials")).unwrap().json_body().unwrap();
    assert!(matches!(trials, Value::Arr(_)), "paramless trials stays a bare array");
    s.stop();
}

#[test]
fn best_endpoint_tracks_the_incumbent() {
    let s = server();
    let mut c = Client::connect(s.addr()).unwrap();
    let (sid, t1) = ask(&mut c, "best");
    // No completed trial yet: nulls, not 404.
    let b = c.get(&format!("/api/studies/{sid}/best")).unwrap().json_body().unwrap();
    assert!(b.get("best_value").is_null());
    assert!(b.get("best_trial").is_null());
    tell(&mut c, t1, 5.0);
    let (_, t2) = ask(&mut c, "best");
    tell(&mut c, t2, 2.0);
    let (_, t3) = ask(&mut c, "best");
    tell(&mut c, t3, 9.0);
    let b = c.get(&format!("/api/studies/{sid}/best")).unwrap().json_body().unwrap();
    assert_eq!(b.get("best_value").as_f64(), Some(2.0));
    assert_eq!(b.get("best_trial").get("id").as_u64(), Some(t2));
    assert_eq!(b.get("best_trial").get("state").as_str(), Some("completed"));
    assert_eq!(c.get("/api/studies/424242/best").unwrap().status, 404);
    s.stop();
}

#[test]
fn events_since_zero_replays_history_in_order() {
    let s = server();
    let mut c = Client::connect(s.addr()).unwrap();
    let (sid, t1) = ask(&mut c, "feed");
    let (_, t2) = ask(&mut c, "feed");
    let (_, t3) = ask(&mut c, "feed");
    tell(&mut c, t1, 3.0);
    tell(&mut c, t2, 1.0);
    tell(&mut c, t3, 2.0);
    let feed = c
        .get(&format!("/api/studies/{sid}/events?since=0&timeout=0"))
        .unwrap()
        .json_body()
        .unwrap();
    assert_eq!(feed.get("watermark").as_u64(), Some(3));
    let events = feed.get("events").as_arr().unwrap();
    assert_eq!(events.len(), 3);
    for (i, (e, (tid, val))) in
        events.iter().zip([(t1, 3.0), (t2, 1.0), (t3, 2.0)]).enumerate()
    {
        assert_eq!(e.get("seq").as_u64(), Some(i as u64 + 1), "dense 1-based seq");
        assert_eq!(e.get("trial_id").as_u64(), Some(tid));
        assert_eq!(e.get("kind").as_str(), Some("completed"));
        assert_eq!(e.get("value").as_f64(), Some(val));
    }
    // Incremental read: since=2 returns exactly the third event.
    let feed = c
        .get(&format!("/api/studies/{sid}/events?since=2&timeout=0"))
        .unwrap()
        .json_body()
        .unwrap();
    let events = feed.get("events").as_arr().unwrap();
    assert_eq!(events.len(), 1);
    assert_eq!(events[0].get("seq").as_u64(), Some(3));
    // Bad parameters and unknown studies.
    assert_eq!(c.get(&format!("/api/studies/{sid}/events?since=abc")).unwrap().status, 422);
    assert_eq!(c.get(&format!("/api/studies/{sid}/events?since=0&timeout=-1")).unwrap().status, 422);
    assert_eq!(c.get(&format!("/api/studies/{sid}/events?since=0&timeout=nan")).unwrap().status, 422);
    assert_eq!(c.get("/api/studies/424242/events?since=0").unwrap().status, 404);
    s.stop();
}

#[test]
fn parked_events_waiter_wakes_with_exactly_the_new_events() {
    let s = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig {
            auth_required: false,
            events_poll_timeout: Duration::from_secs(10),
            ..Default::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(s.addr()).unwrap();
    let (sid, t1) = ask(&mut c, "wake");
    tell(&mut c, t1, 1.0);
    let w = c
        .get(&format!("/api/studies/{sid}/events?since=0&timeout=0"))
        .unwrap()
        .json_body()
        .unwrap()
        .get("watermark")
        .as_u64()
        .unwrap();
    let addr = s.addr();
    let waiter = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        let started = Instant::now();
        let feed = c
            .get(&format!("/api/studies/{sid}/events?since={w}&timeout=8"))
            .unwrap()
            .json_body()
            .unwrap();
        (feed, started.elapsed())
    });
    // Let the waiter park, then complete one more trial.
    std::thread::sleep(Duration::from_millis(200));
    let (_, t2) = ask(&mut c, "wake");
    tell(&mut c, t2, 2.0);
    let (feed, waited) = waiter.join().unwrap();
    assert!(waited < Duration::from_secs(6), "woke by notification, not timeout");
    assert_eq!(feed.get("watermark").as_u64(), Some(w + 1));
    let events = feed.get("events").as_arr().unwrap();
    assert_eq!(events.len(), 1, "exactly the new event");
    assert_eq!(events[0].get("seq").as_u64(), Some(w + 1));
    assert_eq!(events[0].get("trial_id").as_u64(), Some(t2));
    s.stop();
}

#[test]
fn events_timeout_returns_empty_page_with_watermark() {
    let s = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig {
            auth_required: false,
            events_poll_timeout: Duration::from_secs(10),
            ..Default::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(s.addr()).unwrap();
    let (sid, t1) = ask(&mut c, "idle");
    tell(&mut c, t1, 1.0);
    let started = Instant::now();
    let feed = c
        .get(&format!("/api/studies/{sid}/events?since=1&timeout=0.3"))
        .unwrap()
        .json_body()
        .unwrap();
    let waited = started.elapsed();
    assert!(waited >= Duration::from_millis(250), "parked until the deadline");
    assert!(waited < Duration::from_secs(5), "per-request timeout honored, not server cap");
    assert_eq!(feed.get("events").as_arr().unwrap().len(), 0);
    assert_eq!(feed.get("watermark").as_u64(), Some(1));
    // since beyond the watermark also parks, then reports the true
    // (lower) watermark so a confused client can resynchronize.
    let feed = c
        .get(&format!("/api/studies/{sid}/events?since=99&timeout=0.2"))
        .unwrap()
        .json_body()
        .unwrap();
    assert_eq!(feed.get("events").as_arr().unwrap().len(), 0);
    assert_eq!(feed.get("watermark").as_u64(), Some(1));
    // timeout=0 never parks even with no news.
    let started = Instant::now();
    let feed = c
        .get(&format!("/api/studies/{sid}/events?since=1&timeout=0"))
        .unwrap()
        .json_body()
        .unwrap();
    assert!(started.elapsed() < Duration::from_millis(200));
    assert_eq!(feed.get("events").as_arr().unwrap().len(), 0);
    s.stop();
}

#[test]
fn hundred_parked_waiters_do_not_starve_writes_on_four_workers() {
    let s = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig {
            auth_required: false,
            events_poll_timeout: Duration::from_secs(10),
            http: ServerConfig { workers: 4, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(s.addr()).unwrap();
    let (sid, t1) = ask(&mut c, "starve");
    tell(&mut c, t1, 1.0);
    let w = 1u64;

    let addr = s.addr();
    let waiters: Vec<_> = (0..100)
        .map(|_| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let feed = c
                    .get(&format!("/api/studies/{sid}/events?since={w}&timeout=8"))
                    .unwrap()
                    .json_body()
                    .unwrap();
                feed.get("watermark").as_u64().unwrap()
            })
        })
        .collect();

    // Wait until the waiter gauge confirms the pool handed the parked
    // connections off to the pump (they must not pin the 4 workers).
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let m = c.get("/metrics").unwrap();
        let text = String::from_utf8(m.body).unwrap();
        let parked = text
            .lines()
            .find(|l| l.starts_with("hopaas_events_waiters "))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or(0.0);
        if parked >= 90.0 {
            break;
        }
        assert!(Instant::now() < deadline, "waiters never parked (gauge {parked})");
        std::thread::sleep(Duration::from_millis(20));
    }

    // With 100 connections parked, 4 workers must still serve writes
    // promptly: the park handoff frees the worker thread.
    let started = Instant::now();
    for i in 0..20 {
        let (_, tid) = ask(&mut c, "other-study");
        tell(&mut c, tid, i as f64);
    }
    assert!(
        started.elapsed() < Duration::from_secs(5),
        "ask/tell starved behind parked readers: {:?}",
        started.elapsed()
    );

    // Wake everyone with one new event on the watched study.
    let (_, t2) = ask(&mut c, "starve");
    tell(&mut c, t2, 2.0);
    for h in waiters {
        let watermark = h.join().unwrap();
        assert_eq!(watermark, w + 1, "every waiter saw the wake event");
    }
    s.stop();
}

#[test]
fn pagination_fuzz_fixed_seed() {
    let s = server();
    let mut c = Client::connect(s.addr()).unwrap();
    let mut rng = Rng::new(0xC0FFEE);
    let mut sid = 0;
    let mut trial_ids = Vec::new();
    for i in 0..40 {
        let (study, tid) = ask(&mut c, "fuzz");
        sid = study;
        trial_ids.push(tid);
        if rng.chance(0.7) {
            tell(&mut c, tid, i as f64 + rng.below(10) as f64);
        }
    }
    let want = legacy_trial_ids(&mut c, sid);
    assert_eq!(want, trial_ids, "slot order is ask order");

    // Random page walks: any limit reproduces the full set exactly.
    for _ in 0..10 {
        let limit = 1 + rng.below(50) as usize;
        assert_eq!(walk_trials(&mut c, sid, limit), want, "limit={limit}");
    }

    // Random (including stale-epoch) cursors are serviceable: pages are
    // well-formed suffixes of the slot order, never an error.
    for _ in 0..30 {
        let epoch = rng.below(100);
        let index = rng.below(60) as usize;
        let limit = 1 + rng.below(20) as usize;
        let r = c
            .get(&format!("/api/studies/{sid}/trials?limit={limit}&cursor=v1.{epoch}.{index}"))
            .unwrap();
        assert_eq!(r.status, 200);
        let page = r.json_body().unwrap();
        let got: Vec<u64> = page
            .get("trials")
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.get("id").as_u64().unwrap())
            .collect();
        let start = index.min(want.len());
        let expect: Vec<u64> = want[start..].iter().take(limit).copied().collect();
        assert_eq!(got, expect, "cursor v1.{epoch}.{index} limit={limit}");
    }

    // A cursor taken before more writes keeps working afterwards, and a
    // resumed walk lands on the final set: stale reads are never errors.
    let p1 = c
        .get(&format!("/api/studies/{sid}/trials?limit=10"))
        .unwrap()
        .json_body()
        .unwrap();
    let stale = p1.get("next_cursor").as_str().unwrap().to_string();
    for i in 0..5 {
        let (_, tid) = ask(&mut c, "fuzz");
        tell(&mut c, tid, 100.0 + i as f64);
    }
    let grown = legacy_trial_ids(&mut c, sid);
    assert_eq!(grown.len(), 45);
    let mut resumed: Vec<u64> =
        p1.get("trials").as_arr().unwrap().iter().map(|t| t.get("id").as_u64().unwrap()).collect();
    let mut path = format!("/api/studies/{sid}/trials?limit=10&cursor={stale}");
    loop {
        let page = c.get(&path).unwrap().json_body().unwrap();
        resumed
            .extend(page.get("trials").as_arr().unwrap().iter().map(|t| t.get("id").as_u64().unwrap()));
        match page.get("next_cursor").as_str() {
            Some(cur) => path = format!("/api/studies/{sid}/trials?limit=10&cursor={cur}"),
            None => break,
        }
    }
    assert_eq!(resumed, grown, "stale-cursor resume converges on the final set");

    // Malformed cursors: always 422, never a panic or a mis-page.
    for bad in ["v1", "v1.", "v1.1", "v1.1.", "v1.x.1", "v1.1.x", "v0.1.1", "1.1.1", "..", "v1.1.1.1"] {
        let r = c
            .get(&format!("/api/studies/{sid}/trials?limit=5&cursor={bad}"))
            .unwrap();
        assert_eq!(r.status, 422, "cursor {bad:?}");
    }
    s.stop();
}

//! Self-tests for `hopaas-lint` (`hopaas::analysis`): every rule gets a
//! positive fixture (the lint must catch it), a negative fixture (the
//! lint must accept it), and a suppression fixture (`lint:allow` must
//! silence it) — plus baseline round-trips and the real-tree gate that
//! keeps the production sources lint-clean.

use hopaas::analysis::{self, baseline, lint_source, lint_sources, Finding, HIERARCHY};
use std::path::Path;

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------
// Rule 1: lock_order
// ---------------------------------------------------------------------

#[test]
fn lock_order_catches_descending_acquisition() {
    // `state` is the shard class (level 20), `directory` the registry
    // class (level 10): taking the directory under a shard guard
    // inverts the canonical order.
    let src = r#"
        impl Engine {
            fn bad(&self) {
                let g = self.state.lock_safe();
                let d = self.directory.write_safe();
                g.touch(&d);
            }
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["lock_order"], "{findings:?}");
    assert_eq!(findings[0].func, "Engine::bad");
    assert_eq!(findings[0].detail, "shard<-directory");
}

#[test]
fn lock_order_accepts_ascending_and_dropped_guards() {
    let src = r#"
        impl Engine {
            fn ascending(&self) {
                let d = self.directory.read_safe();
                let g = self.state.lock_safe();
                let q = self.queue.lock_safe();
                g.touch(&d, &q);
            }
            fn scoped(&self) {
                {
                    let g = self.state.lock_safe();
                    g.touch();
                }
                let d = self.directory.write_safe();
                d.push(1);
            }
            fn explicit_drop(&self) {
                let g = self.state.lock_safe();
                drop(g);
                let d = self.directory.write_safe();
                d.push(1);
            }
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

#[test]
fn lock_order_suppressed_by_allow() {
    let src = r#"
        impl Engine {
            fn exempt(&self) {
                let g = self.state.lock_safe();
                // lint:allow(lock_order): fixture — order proven safe by construction.
                let d = self.directory.write_safe();
                g.touch(&d);
            }
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

#[test]
fn lock_order_propagates_through_helpers() {
    // `helper` acquires the WAL queue (level 40); calling it while
    // holding the WAL ledger (level 42) is an inversion even though the
    // acquisition is one call away.
    let src = r#"
        impl Engine {
            fn helper(&self) {
                let q = self.queue.lock_safe();
                q.push_back(1);
            }
            fn bad(&self) {
                let g = self.ledger.lock_safe();
                self.helper();
                g.touch();
            }
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["lock_order"], "{findings:?}");
    assert_eq!(findings[0].detail, "wal_ledger<-helper()");
}

#[test]
fn lock_order_tracks_declared_effects() {
    // `lock_shard` is a declared effect: it returns a live shard guard,
    // so a later directory acquisition inverts 20 -> 10.
    let src = r#"
        impl Engine {
            fn bad(&self, idx: usize) {
                let state = self.lock_shard(idx);
                let d = self.directory.write_safe();
                state.touch(&d);
            }
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["lock_order"], "{findings:?}");
    assert_eq!(findings[0].detail, "shard<-directory");
}

// ---------------------------------------------------------------------
// Rule 2: guard_blocking
// ---------------------------------------------------------------------

#[test]
fn guard_blocking_catches_fsync_under_guard() {
    let src = r#"
        impl Wal {
            fn bad(&self, file: &std::fs::File) {
                let g = self.ledger.lock_safe();
                file.sync_all().ok();
                g.touch();
            }
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["guard_blocking"], "{findings:?}");
    assert_eq!(findings[0].detail, "ledger-across-sync_all");
}

#[test]
fn guard_blocking_accepts_sync_after_release() {
    let src = r#"
        impl Wal {
            fn good(&self, file: &std::fs::File) {
                {
                    let g = self.ledger.lock_safe();
                    g.touch();
                }
                file.sync_all().ok();
            }
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

#[test]
fn guard_blocking_suppressed_by_allow() {
    let src = r#"
        impl Wal {
            fn exempt(&self, file: &std::fs::File) {
                let g = self.ledger.lock_safe();
                // lint:allow(guard_blocking): fixture — this lock IS the serialization point.
                file.sync_all().ok();
                g.touch();
            }
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Rule 3: determinism
// ---------------------------------------------------------------------

#[test]
fn determinism_catches_clock_in_det_root_fn() {
    // `apply_event` is a deterministic root by name, whatever file it
    // lives in.
    let src = r#"
        impl Engine {
            fn apply_event(&mut self) {
                let t0 = std::time::Instant::now();
                self.note(t0);
            }
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["determinism"], "{findings:?}");
    assert_eq!(findings[0].detail, "clock-Instant::now");
}

#[test]
fn determinism_catches_rng_in_sampler_dir() {
    // Everything under coordinator/samplers/ is a deterministic root by
    // path.
    let src = r#"
        fn propose(n: usize) -> f64 {
            let mut r = thread_rng();
            r.gen()
        }
    "#;
    let findings = lint_source("src/coordinator/samplers/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["determinism"], "{findings:?}");
}

#[test]
fn determinism_ignores_clocks_outside_det_roots() {
    let src = r#"
        fn handle_request() -> u64 {
            let t0 = std::time::Instant::now();
            t0.elapsed().as_micros() as u64
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

#[test]
fn determinism_suppressed_by_allow() {
    let src = r#"
        impl Engine {
            fn apply_event(&mut self) {
                // lint:allow(determinism): fixture — span timing only, never applied state.
                let t0 = std::time::Instant::now();
                self.note(t0);
            }
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Rule 4: unwrap_boundary
// ---------------------------------------------------------------------

#[test]
fn unwrap_boundary_catches_parse_unwrap() {
    let src = r#"
        fn bad(s: &str) -> u32 {
            s.parse().unwrap()
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["unwrap_boundary"], "{findings:?}");
    assert_eq!(findings[0].detail, "parse-unwrap");
}

#[test]
fn unwrap_boundary_catches_turbofish_parse_unwrap() {
    let src = r#"
        fn bad(s: &str) -> u32 {
            s.parse::<u32>().expect("number")
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["unwrap_boundary"], "{findings:?}");
    assert_eq!(findings[0].detail, "parse-unwrap");
}

#[test]
fn unwrap_boundary_catches_lock_poison_unwrap() {
    let src = r#"
        impl S {
            fn bad(&self) -> usize {
                let g = self.m.lock().unwrap();
                g.len()
            }
        }
    "#;
    let findings = lint_source("src/fixture.rs", src);
    assert_eq!(rules_of(&findings), vec!["unwrap_boundary"], "{findings:?}");
    assert_eq!(findings[0].detail, "m.lock-unwrap");
}

#[test]
fn unwrap_boundary_accepts_handled_results_and_safe_locks() {
    let src = r#"
        impl S {
            fn good(&self, s: &str) -> u32 {
                let g = self.m.lock_safe();
                g.note();
                s.parse().unwrap_or(0)
            }
            fn recovered(&self) -> usize {
                self.m.lock().unwrap_or_else(|p| p.into_inner()).len()
            }
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

#[test]
fn unwrap_boundary_ignores_test_code() {
    let src = r#"
        fn shipping(s: &str) -> Result<u32, std::num::ParseIntError> {
            s.parse()
        }

        #[cfg(test)]
        mod tests {
            #[test]
            fn exercise() {
                let v: u32 = "7".parse().unwrap();
                assert_eq!(v, 7);
            }
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

#[test]
fn unwrap_boundary_suppressed_by_allow() {
    let src = r#"
        fn exempt(b: &[u8]) -> &str {
            // lint:allow(unwrap_boundary): fixture — validated ASCII, not an input boundary.
            std::str::from_utf8(b).unwrap()
        }
    "#;
    assert!(lint_source("src/fixture.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Baseline machinery
// ---------------------------------------------------------------------

#[test]
fn baseline_roundtrip_covers_and_goes_stale() {
    let bad = r#"
        fn bad(s: &str) -> u32 {
            s.parse().unwrap()
        }
    "#;
    let findings = lint_source("src/fixture.rs", bad);
    assert_eq!(findings.len(), 1);

    // A freshly written baseline covers the finding...
    let base = baseline::parse(&baseline::render(&findings));
    let diff = baseline::diff(&findings, &base);
    assert!(diff.new.is_empty());
    assert!(diff.stale.is_empty());
    assert_eq!(diff.baselined, 1);

    // ...an empty baseline reports it as new...
    let diff = baseline::diff(&findings, &Default::default());
    assert_eq!(diff.new.len(), 1);

    // ...and once the code is fixed, the old entry is stale (the
    // "baselines only shrink" rule).
    let diff = baseline::diff(&[], &base);
    assert!(diff.new.is_empty());
    assert_eq!(diff.stale.len(), 1);
}

#[test]
fn baseline_keys_are_line_number_free() {
    let v1 = lint_source("src/fixture.rs", "fn bad(s: &str) -> u32 { s.parse().unwrap() }");
    let v2 = lint_source(
        "src/fixture.rs",
        "// a comment pushing the code down\n\nfn bad(s: &str) -> u32 { s.parse().unwrap() }",
    );
    assert_ne!(v1[0].line, v2[0].line);
    assert_eq!(v1[0].key(), v2[0].key());
}

// ---------------------------------------------------------------------
// The hierarchy table itself
// ---------------------------------------------------------------------

#[test]
fn hierarchy_is_strictly_ascending_and_unambiguous() {
    let mut seen = std::collections::HashSet::new();
    let mut last = 0u32;
    for (i, c) in HIERARCHY.iter().enumerate() {
        assert!(i == 0 || c.level > last, "levels must strictly ascend at `{}`", c.name);
        last = c.level;
        for r in c.receivers {
            assert!(seen.insert(*r), "receiver `{r}` appears in two lock classes");
        }
    }
}

// ---------------------------------------------------------------------
// The real tree: the gate CI enforces
// ---------------------------------------------------------------------

/// The committed production sources must be lint-clean against the
/// committed baseline — and the baseline itself must be empty for the
/// files this PR cleaned up (engine, views, group).
#[test]
fn production_tree_is_lint_clean() {
    let root = Path::new("src");
    let findings = analysis::lint_tree(root).expect("scan src/");
    let base_text = std::fs::read_to_string("lint-baseline.txt").unwrap_or_default();
    let base = baseline::parse(&base_text);

    let diff = baseline::diff(&findings, &base);
    let new: Vec<String> = diff.new.iter().map(|f| f.render()).collect();
    assert!(new.is_empty(), "unbaselined findings:\n{}", new.join("\n"));
    assert!(diff.stale.is_empty(), "stale baseline entries: {:?}", diff.stale);

    for file in ["coordinator/engine.rs", "coordinator/views.rs", "store/group.rs"] {
        assert!(
            !base.iter().any(|k| k.contains(file)),
            "baseline must be empty for {file}"
        );
        assert!(
            !findings.iter().any(|f| f.file.ends_with(file)),
            "{file} must be finding-free"
        );
    }
}

/// The in-memory lint and the on-disk file set agree on labels: every
/// finding (if any ever appears) points at a real `src/…` path.
#[test]
fn collected_sources_have_stable_labels() {
    let sources = analysis::collect_sources(Path::new("src")).expect("collect");
    assert!(sources.iter().any(|(l, _)| l == "src/lib.rs"));
    assert!(sources.iter().all(|(l, _)| l.starts_with("src/")));
    // testutil is scaffolding, exempt from production lock discipline.
    assert!(sources.iter().all(|(l, _)| !l.contains("testutil")));
    // Sorted, deduplicated labels => deterministic reports.
    let mut labels: Vec<&String> = sources.iter().map(|(l, _)| l).collect();
    let n = labels.len();
    labels.dedup();
    assert_eq!(labels.len(), n);

    let findings = lint_sources(&sources);
    for f in &findings {
        assert!(f.file.starts_with("src/"), "bad label {}", f.file);
    }
}

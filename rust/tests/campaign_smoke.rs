//! E3 smoke — a >20-node multi-site campaign over real HTTP, checking
//! the §4 coordination claims end to end: concurrent diverse nodes, one
//! shared study, optimizer progress, dashboard series consistency.

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::Objective;
use hopaas::worker::{Campaign, HopaasClient};

#[test]
fn twenty_four_nodes_share_one_study() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();

    let mut campaign = Campaign::new(server.addr(), "x".into(), Objective::Sphere);
    campaign.n_nodes = 24; // "more than twenty concurrent and diverse nodes"
    campaign.max_trials = 150;
    campaign.steps_per_trial = 8;
    campaign.step_cost_us = 100;
    let report = campaign.run().unwrap();

    // One study only, despite 24 independent clients defining it.
    let studies = server.engine.studies_json();
    assert_eq!(studies.as_arr().unwrap().len(), 1, "all asks joined one study");
    let sid = studies.at(0).get("id").as_u64().unwrap();

    // Server-side and client-side accounting agree.
    let n_completed = studies.at(0).get("n_completed").as_i64().unwrap() as u64;
    assert_eq!(n_completed, report.completed);
    let n_pruned = studies.at(0).get("n_pruned").as_i64().unwrap() as u64;
    assert_eq!(n_pruned, report.pruned);

    // All four site profiles contributed completions.
    let sites: Vec<&str> = report.by_site.iter().map(|(s, _)| s.as_str()).collect();
    for site in ["marconi100", "infn-cloud", "private", "commercial-spot"] {
        assert!(sites.contains(&site), "missing site {site}");
    }

    // TPE made progress: best well below the random-expectation (~8 for
    // a 4-D sphere over [-5,5]^4 ≈ E[Σx²] = 4·25/3 ≈ 33; best of 100+
    // trials should be far smaller).
    let best = report.best.unwrap();
    assert!(best < 15.0, "best={best}");

    // Dashboard series: every trial's points are step-monotone.
    let series = server.engine.series_json(sid).unwrap();
    for t in series.as_arr().unwrap() {
        let pts = t.get("points").as_arr().unwrap();
        for w in pts.windows(2) {
            assert!(
                w[0].at(0).as_f64().unwrap() < w[1].at(0).as_f64().unwrap(),
                "steps strictly increasing"
            );
        }
    }
    server.stop();
}

#[test]
fn dozens_of_studies_concurrently() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    let addr = server.addr();

    // 12 distinct studies (name differs) driven by 4 nodes each, all at
    // once — 48 concurrent clients against one server.
    let handles: Vec<_> = (0..12)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Campaign::new(addr, "x".into(), Objective::Branin);
                c.study_name = format!("multi-{i}");
                c.n_nodes = 4;
                c.max_trials = 16;
                c.steps_per_trial = 4;
                c.step_cost_us = 50;
                c.seed = i as u64;
                c.run().unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert!(r.completed + r.pruned + r.preempted >= 12);
    }
    assert_eq!(server.engine.n_studies(), 12);
    server.stop();
}

#[test]
fn samplers_all_work_over_http() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    for sampler in ["random", "grid", "qmc", "tpe", "gp", "cmaes"] {
        let mut campaign = Campaign::new(server.addr(), "x".into(), Objective::Branin);
        campaign.study_name = format!("sampler-{sampler}");
        campaign.sampler = match sampler {
            "random" => "random",
            "grid" => "grid",
            "qmc" => "qmc",
            "gp" => "gp",
            "cmaes" => "cmaes",
            _ => "tpe",
        };
        campaign.pruner = None;
        campaign.n_nodes = 4;
        campaign.max_trials = 24;
        campaign.steps_per_trial = 2;
        campaign.step_cost_us = 0;
        let report = campaign.run().unwrap();
        assert!(
            report.completed >= 20,
            "{sampler}: completed {}",
            report.completed
        );
        assert!(report.best.unwrap().is_finite(), "{sampler}");
    }
    server.stop();
}

#[test]
fn unknown_sampler_is_client_error_not_crash() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    let mut c = HopaasClient::connect(server.addr(), "x".into()).unwrap();
    let spec = hopaas::worker::StudySpec::new("bad")
        .uniform("x", 0.0, 1.0)
        .sampler("not-a-sampler");
    match c.ask(&spec) {
        Err(hopaas::worker::WorkerError::Api { status: 422, .. }) => {}
        other => panic!("expected 422, got {other:?}"),
    }
    // Server still healthy.
    assert!(c.version().is_ok());
    server.stop();
}

//! Replication e2e: a read-only follower bootstraps from a live
//! primary over HTTP (snapshot bundle + WAL stream), serves the read
//! path byte-for-byte, rejects writes with a `primary` hint that the
//! worker client transparently follows, and — after the primary dies —
//! promotes in place and takes over writes without losing one
//! acknowledged tell.

use hopaas::coordinator::engine::EngineConfig;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::http::Client;
use hopaas::worker::{HopaasClient, StudySpec};
use std::net::SocketAddr;
use std::time::{Duration, Instant};

struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!("hopaas-repl-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn primary_config(dir: &std::path::Path) -> HopaasConfig {
    HopaasConfig {
        auth_required: false,
        data_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

fn follower_config(dir: &std::path::Path, primary: SocketAddr) -> HopaasConfig {
    HopaasConfig {
        auth_required: false,
        data_dir: Some(dir.to_path_buf()),
        engine: EngineConfig {
            follower: true,
            primary_url: Some(format!("http://{primary}")),
            ..Default::default()
        },
        repl_poll_timeout: Duration::from_millis(200),
        ..Default::default()
    }
}

/// Block until the follower's cursor reaches `target` (a primary
/// `next_seq` captured after the workload settled).
fn wait_caught_up(follower: &HopaasServer, target: u64) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while follower.engine.repl_next() < target {
        assert!(
            Instant::now() < deadline,
            "follower stuck at seq {} of {target}",
            follower.engine.repl_next()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn spec() -> StudySpec {
    StudySpec::new("repl-study").uniform("x", 0.0, 1.0).sampler("random")
}

#[test]
fn follower_bootstraps_replicates_and_promotes() {
    let dir_p = TempDir::new("primary");
    let dir_f = TempDir::new("follower");

    let primary = HopaasServer::start("127.0.0.1:0", primary_config(&dir_p.0)).unwrap();
    assert!(!primary.replicating(), "a primary runs no applier");
    let mut c = HopaasClient::connect(primary.addr(), "x".into()).unwrap();

    // Pre-bootstrap history, partly folded into a snapshot so the cold
    // follower exercises the manifest-bundle path, partly left in the
    // live log so it exercises the stream tail.
    let mut told: Vec<(u64, f64)> = Vec::new();
    for i in 0..6 {
        let t = c.ask(&spec()).unwrap();
        c.tell(&t, i as f64).unwrap();
        told.push((t.trial_id, i as f64));
    }
    primary.engine.compact().unwrap();
    for i in 0..4 {
        let t = c.ask(&spec()).unwrap();
        let v = 10.0 + i as f64;
        c.tell(&t, v).unwrap();
        told.push((t.trial_id, v));
    }

    let follower =
        HopaasServer::start("127.0.0.1:0", follower_config(&dir_f.0, primary.addr())).unwrap();
    assert!(follower.replicating(), "follower must run the applier");
    wait_caught_up(&follower, primary.engine.repl_source().unwrap().next_seq());

    // The whole read path is served locally, byte-identical to the
    // primary at the replicated epoch.
    let sid = c.studies().unwrap().at(0).get("id").as_u64().unwrap();
    let mut raw_p = Client::connect(primary.addr()).unwrap();
    let mut raw_f = Client::connect(follower.addr()).unwrap();
    for path in ["/api/studies".to_string(), format!("/api/studies/{sid}/trials")] {
        let a = raw_p.get(&path).unwrap();
        let b = raw_f.get(&path).unwrap();
        assert_eq!(a.status, 200, "{path}");
        assert_eq!(b.status, 200, "{path}");
        assert_eq!(a.body, b.body, "page {path} diverged between primary and follower");
    }
    // Role surfaces in /api/stats on both sides.
    let stats_f = raw_f.get("/api/stats").unwrap().json_body().unwrap();
    assert_eq!(stats_f.get("repl").get("role").as_str(), Some("follower"));
    assert_eq!(stats_f.get("repl").get("writable").as_bool(), Some(false));
    let stats_p = raw_p.get("/api/stats").unwrap().json_body().unwrap();
    assert_eq!(stats_p.get("repl").get("role").as_str(), Some("primary"));

    // Direct writes to the follower are refused with the primary hint.
    let resp = raw_f.post_json("/api/ask/x", &spec().to_body()).unwrap();
    assert_eq!(resp.status, 503);
    let body = resp.json_body().unwrap();
    assert_eq!(body.get("detail").as_str(), Some("read-only follower"));
    assert_eq!(
        body.get("primary").as_str(),
        Some(format!("http://{}", primary.addr()).as_str())
    );

    // The worker client pointed at the follower follows the hint and
    // lands the write on the primary (satellite: client failover).
    let mut via_follower = HopaasClient::connect(follower.addr(), "x".into()).unwrap();
    let t = via_follower.ask(&spec()).unwrap();
    via_follower.tell(&t, 42.0).unwrap();
    told.push((t.trial_id, 42.0));
    assert_eq!(via_follower.addr(), primary.addr(), "client must have re-dialed the primary");

    // Primary dies; the caught-up follower promotes exactly once.
    wait_caught_up(&follower, primary.engine.repl_source().unwrap().next_seq());
    primary.stop();
    let empty = hopaas::json::parse("{}").unwrap();
    let resp = raw_f.post_json("/api/repl/promote", &empty).unwrap();
    assert_eq!(resp.status, 200, "promote failed: {:?}", String::from_utf8_lossy(&resp.body));
    let body = resp.json_body().unwrap();
    assert_eq!(body.get("role").as_str(), Some("primary"));
    assert_eq!(body.get("writable").as_bool(), Some(true));
    assert!(!follower.replicating(), "promotion must seal the applier");
    // A second promote is a conflict, not a double flip.
    let resp = raw_f.post_json("/api/repl/promote", &empty).unwrap();
    assert_eq!(resp.status, 409);

    // Every acknowledged tell survived the failover, and the promoted
    // node takes new writes durably.
    let mut c2 = HopaasClient::connect(follower.addr(), "x".into()).unwrap();
    let trials = follower.engine.trials_json(sid).unwrap();
    for (id, v) in &told {
        let t = trials
            .as_arr()
            .unwrap()
            .iter()
            .find(|t| t.get("id").as_u64() == Some(*id))
            .unwrap_or_else(|| panic!("trial {id} lost in failover"));
        assert_eq!(t.get("value").as_f64(), Some(*v), "value diverged on trial {id}");
    }
    let t = c2.ask(&spec()).unwrap();
    c2.tell(&t, -1.0).unwrap();
    assert_eq!(c2.best_value(sid).unwrap(), Some(-1.0));
    follower.stop();
}

#[test]
fn follower_long_poll_log_delivers_live_batches() {
    // A parked `/api/repl/log` poll on the primary must wake when the
    // next group commit publishes, not at its deadline.
    let dir_p = TempDir::new("longpoll");
    let primary = HopaasServer::start("127.0.0.1:0", primary_config(&dir_p.0)).unwrap();
    let mut c = HopaasClient::connect(primary.addr(), "x".into()).unwrap();
    let t = c.ask(&spec()).unwrap();
    c.tell(&t, 1.0).unwrap();

    let from = primary.engine.repl_source().unwrap().next_seq();
    let addr = primary.addr();
    let poller = std::thread::spawn(move || {
        let mut raw = Client::connect(addr).unwrap();
        let t0 = Instant::now();
        let resp = raw
            .get(&format!("/api/repl/log?from={from}&timeout_ms=5000"))
            .unwrap();
        (resp, t0.elapsed())
    });
    std::thread::sleep(Duration::from_millis(100));
    let t2 = c.ask(&spec()).unwrap();
    c.tell(&t2, 2.0).unwrap();
    let (resp, waited) = poller.join().unwrap();
    assert_eq!(resp.status, 200);
    let body = resp.json_body().unwrap();
    let records = body.get("records").as_arr().unwrap();
    assert!(!records.is_empty(), "live batch must be delivered");
    assert!(
        waited < Duration::from_secs(4),
        "poll should wake on publish, waited {waited:?}"
    );
    assert!(body.get("next").as_u64().unwrap() > from);

    // A cursor below the floor after eviction answers 410 — here the
    // buffer is intact, so any in-window cursor pages forward instead.
    let resp = raw_log(&addr, 0);
    assert_eq!(resp.status, 200);
    primary.stop();
}

fn raw_log(addr: &SocketAddr, from: u64) -> hopaas::http::Response {
    let mut raw = Client::connect(*addr).unwrap();
    raw.get(&format!("/api/repl/log?from={from}")).unwrap()
}

//! E9 integration — the multi-objective protocol over real HTTP:
//! array `direction`, vector `tell`, Pareto endpoint, recovery.

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::multi::MoProblem;
use hopaas::worker::{HopaasClient, StudySpec, WorkerError};

fn server() -> HopaasServer {
    HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap()
}

fn mo_spec(name: &str) -> StudySpec {
    StudySpec::new(name)
        .properties_json(MoProblem::Zdt1.properties())
        .directions(&["minimize", "minimize"])
        .sampler("nsga2")
}

#[test]
fn mo_workflow_over_http() {
    let s = server();
    let mut c = HopaasClient::connect(s.addr(), "x".into()).unwrap();
    let spec = mo_spec("mo-wf");
    let mut study_id = 0;
    for _ in 0..20 {
        let t = c.ask(&spec).unwrap();
        study_id = t.study_id;
        let [f1, f2] = MoProblem::Zdt1.eval_params(&t.params);
        c.tell_values(&t, &[f1, f2]).unwrap();
    }
    // Pareto endpoint returns a mutually non-dominated set.
    let front = c.pareto(study_id).unwrap();
    let pts: Vec<(f64, f64)> = front
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| {
            let v = t.get("values");
            (v.at(0).as_f64().unwrap(), v.at(1).as_f64().unwrap())
        })
        .collect();
    assert!(!pts.is_empty());
    for (i, a) in pts.iter().enumerate() {
        for (j, b) in pts.iter().enumerate() {
            if i != j {
                let dominates = a.0 <= b.0 && a.1 <= b.1 && (a.0 < b.0 || a.1 < b.1);
                assert!(!dominates, "front not mutually non-dominated: {a:?} vs {b:?}");
            }
        }
    }
    // Summary carries MO fields.
    let study = s.engine.study_json(study_id).unwrap();
    assert_eq!(study.get("directions").at(0).as_str(), Some("minimize"));
    assert_eq!(study.get("pareto_size").as_u64(), Some(pts.len() as u64));
    s.stop();
}

#[test]
fn mo_arity_and_type_errors() {
    let s = server();
    let mut c = HopaasClient::connect(s.addr(), "x".into()).unwrap();
    let t = c.ask(&mo_spec("mo-err")).unwrap();
    // Wrong arity -> 422.
    match c.tell_values(&t, &[1.0]) {
        Err(WorkerError::Api { status: 422, .. }) => {}
        other => panic!("expected 422, got {other:?}"),
    }
    // Scalar tell into an MO study is tolerated (completes with a single
    // value) or rejected — either way it must not wedge the server.
    let _ = c.tell(&t, 1.0);
    // values into a single-objective study -> 422.
    let so = StudySpec::new("so").uniform("x", 0.0, 1.0).sampler("random");
    let t2 = c.ask(&so).unwrap();
    match c.tell_values(&t2, &[1.0, 2.0]) {
        Err(WorkerError::Api { status: 422, .. }) => {}
        other => panic!("expected 422, got {other:?}"),
    }
    // Unsupported sampler for MO -> 422.
    let bad = StudySpec::new("mo-bad")
        .properties_json(MoProblem::Zdt1.properties())
        .directions(&["minimize", "minimize"])
        .sampler("gp");
    match c.ask(&bad) {
        Err(WorkerError::Api { status: 422, .. }) => {}
        other => panic!("expected 422, got {other:?}"),
    }
    s.stop();
}

#[test]
fn mo_survives_restart() {
    let dir = std::env::temp_dir().join(format!("hopaas-mo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let config = || HopaasConfig {
        auth_required: false,
        data_dir: Some(dir.clone()),
        ..Default::default()
    };
    let n_front;
    let study_id;
    {
        let s = HopaasServer::start("127.0.0.1:0", config()).unwrap();
        let mut c = HopaasClient::connect(s.addr(), "x".into()).unwrap();
        let spec = mo_spec("mo-dur");
        let mut sid = 0;
        for _ in 0..12 {
            let t = c.ask(&spec).unwrap();
            sid = t.study_id;
            let [f1, f2] = MoProblem::Zdt1.eval_params(&t.params);
            c.tell_values(&t, &[f1, f2]).unwrap();
        }
        study_id = sid;
        n_front = s.engine.pareto_json(sid).unwrap().as_arr().unwrap().len();
        assert!(n_front > 0);
        s.stop();
    }
    let s = HopaasServer::start("127.0.0.1:0", config()).unwrap();
    let recovered = s.engine.pareto_json(study_id).unwrap();
    assert_eq!(recovered.as_arr().unwrap().len(), n_front, "pareto front recovered");
    let study = s.engine.study_json(study_id).unwrap();
    assert_eq!(study.get("n_completed").as_i64(), Some(12));
    s.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mo_and_so_studies_coexist() {
    let s = server();
    let mut c = HopaasClient::connect(s.addr(), "x".into()).unwrap();
    let mo = c.ask(&mo_spec("coexist-mo")).unwrap();
    let so = c
        .ask(&StudySpec::new("coexist-so").uniform("x", 0.0, 1.0).sampler("tpe"))
        .unwrap();
    assert_ne!(mo.study_id, so.study_id);
    c.tell_values(&mo, &[0.5, 0.5]).unwrap();
    c.tell(&so, 0.1).unwrap();
    let studies = c.studies().unwrap();
    assert_eq!(studies.as_arr().unwrap().len(), 2);
    s.stop();
}

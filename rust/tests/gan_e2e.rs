//! E6 smoke — the full three-layer stack in one test: HOPAAS over HTTP
//! orchestrating real PJRT GAN trials (Pallas kernels inside the HLO).
//! Skipped when `make artifacts` has not run.

use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::gan::{GanHyper, GanTrainer};
use hopaas::json::Value;
use hopaas::runtime::Runtime;
use hopaas::worker::{HopaasClient, StudySpec};
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Runtime::open(dir).unwrap()))
}

#[test]
fn hopaas_drives_real_gan_trials() {
    let Some(runtime) = runtime() else { return };
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    let mut client = HopaasClient::connect(server.addr(), "x".into()).unwrap();

    let spec = StudySpec::new("gan-e2e")
        .categorical("width", vec![Value::Num(32.0)])
        .categorical("depth", vec![Value::Num(2.0)])
        .loguniform("lr_g", 5e-4, 5e-3)
        .loguniform("lr_d", 5e-4, 5e-3)
        .uniform("leak", 0.05, 0.3)
        .sampler("tpe");

    let mut values = Vec::new();
    for _ in 0..3 {
        let trial = client.ask(&spec).unwrap();
        let p = &trial.params;
        let hp = GanHyper {
            lr_g: p.get("lr_g").as_f64().unwrap() as f32,
            lr_d: p.get("lr_d").as_f64().unwrap() as f32,
            beta1: 0.5,
            beta2: 0.9,
            leak: p.get("leak").as_f64().unwrap() as f32,
        };
        let mut trainer = GanTrainer::new(runtime.clone(), 32, 2, trial.trial_id).unwrap();
        trainer.train(60, &hp).unwrap();
        let w1 = trainer.evaluate_with_leak(hp.leak).unwrap() as f64;
        assert!(w1.is_finite() && w1 > 0.0);
        client.tell(&trial, w1).unwrap();
        values.push(w1);
    }

    // Server recorded all three with matching best.
    let studies = server.engine.studies_json();
    assert_eq!(studies.at(0).get("n_completed").as_i64(), Some(3));
    let best = studies.at(0).get("best_value").as_f64().unwrap();
    let min = values.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!((best - min).abs() < 1e-12);
    // Training at reasonable hyperparameters beats an untrained model.
    let mut untrained = GanTrainer::new(runtime, 32, 2, 12345).unwrap();
    let untrained_w1 = untrained.evaluate().unwrap() as f64;
    assert!(
        min < untrained_w1,
        "trained {min} should beat untrained {untrained_w1}"
    );
    server.stop();
}

#[test]
fn pruning_a_gan_trial_mid_training_works() {
    let Some(runtime) = runtime() else { return };
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    let mut client = HopaasClient::connect(server.addr(), "x".into()).unwrap();
    let spec = StudySpec::new("gan-prune")
        .uniform("leak", 0.05, 0.3)
        .pruner_json({
            let mut p = Value::obj();
            p.set("name", "threshold").set("upper", 0.2);
            Value::Obj(p)
        });

    let trial = client.ask(&spec).unwrap();
    let mut trainer = GanTrainer::new(runtime, 32, 2, trial.trial_id).unwrap();
    trainer.train(2, &GanHyper::default()).unwrap();
    let w1 = trainer.evaluate().unwrap() as f64;
    // 2 steps in, W1 is still above the tight threshold → pruner fires.
    assert!(w1 > 0.2, "near-untrained W1 should exceed 0.2, got {w1}");
    let pruned = client.should_prune(&trial, 1, w1).unwrap();
    assert!(pruned);
    let studies = server.engine.studies_json();
    assert_eq!(studies.at(0).get("n_pruned").as_i64(), Some(1));
    server.stop();
}

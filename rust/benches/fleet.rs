//! Fleet scheduling benchmark: worker-bound ask/tell throughput (lease
//! bind + quota admission on every ask) and the lease-expiry requeue
//! rate, at 1 / 4 / 8 shards.
//!
//! The lease layer sits on the hot path of every worker-bound ask: an
//! admission check + slot reservation before sampling, and a
//! `lease_bind` record committed in the same group-commit batch as
//! `trial_new`. This bench tracks what that costs relative to the bare
//! ask path and how fast a mass-preemption (a vanished site) is
//! requeued. Results go to `BENCH_fleet.json` at the repository root so
//! CI can archive the trajectory next to the recovery bench.
//!
//! Run: `cargo bench --bench fleet [-- --trials N]` (default 20_000).

use hopaas::bench::{fmt_duration, Table};
use hopaas::coordinator::engine::{ApiError, Engine, EngineConfig};
use hopaas::json::{parse, Value};
use std::sync::Arc;
use std::time::Instant;

const N_STUDIES: usize = 8;
const N_WORKER_THREADS: usize = 8;

fn ask_body(study: usize, worker: Option<u64>) -> Value {
    let mut v = parse(&format!(
        r#"{{
        "study_name": "fleet-{study}",
        "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
        "direction": "minimize",
        "sampler": {{"name": "random"}}
    }}"#
    ))
    .unwrap();
    if let (Some(w), Value::Obj(o)) = (worker, &mut v) {
        o.set("worker", w);
    }
    v
}

/// Multi-threaded ask+tell loop; `fleet` = worker-bound with leases.
fn campaign(engine: &Arc<Engine>, trials: u64, fleet: bool) -> f64 {
    let per_thread = trials / N_WORKER_THREADS as u64;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..N_WORKER_THREADS {
            let engine = engine.clone();
            scope.spawn(move || {
                let worker = fleet.then(|| {
                    let site = if t % 2 == 0 { "site-a" } else { "site-b" };
                    engine
                        .register_worker(&format!("bench-{t}"), site, "gpu")
                        .unwrap()
                        .0
                });
                for i in 0..per_thread {
                    let study = (t + i as usize) % N_STUDIES;
                    let r = loop {
                        match engine.ask(&ask_body(study, worker)) {
                            Ok(r) => break r,
                            Err(ApiError::Quota(_)) => std::thread::yield_now(),
                            Err(e) => panic!("ask: {e}"),
                        }
                    };
                    engine.tell(r.trial_id, i as f64).unwrap();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let trials: u64 = args
        .iter()
        .position(|a| a == "--trials")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);

    println!(
        "\nfleet scheduling: {trials} told trials, {N_WORKER_THREADS} workers, {N_STUDIES} studies\n"
    );
    let table = Table::new(
        &["shards", "mode", "wall", "trials/s", "vs bare"],
        &[8, 10, 12, 12, 10],
    );
    let mut rows: Vec<Value> = Vec::new();
    for &shards in &[1usize, 4, 8] {
        let mut bare_rate = 0.0f64;
        for fleet in [false, true] {
            let engine = Arc::new(Engine::in_memory(EngineConfig {
                n_shards: shards,
                // Quotas on, generously sized: the admission check runs
                // without the denial/backoff path dominating.
                site_quota: if fleet { 64 } else { 0 },
                lease_timeout: Some(3600.0),
                ..Default::default()
            }));
            let wall = campaign(&engine, trials, fleet);
            let rate = trials as f64 / wall;
            if !fleet {
                bare_rate = rate;
            }
            let relative = rate / bare_rate.max(1e-9);
            table.row(&[
                &shards.to_string(),
                if fleet { "leased" } else { "bare" },
                &fmt_duration(wall),
                &format!("{rate:.0}"),
                &format!("{relative:.2}x"),
            ]);
            let mut row = Value::obj();
            row.set("shards", shards)
                .set("mode", if fleet { "leased" } else { "bare" })
                .set("wall_s", wall)
                .set("trials_per_s", rate)
                .set("relative_to_bare", relative);
            rows.push(Value::Obj(row));
        }
    }

    // Mass-preemption requeue rate: one worker holds K leases, its
    // lease expires, and every trial must be requeued durably… here
    // in-memory, so the number isolates the engine-side sweep cost.
    let k = (trials / 4).max(1);
    let engine = Engine::in_memory(EngineConfig {
        lease_timeout: Some(0.001),
        requeue_max: 2,
        ..Default::default()
    });
    let (w, _) = engine.register_worker("doomed", "spot", "gpu").unwrap();
    for i in 0..k {
        engine.ask(&ask_body(i as usize % N_STUDIES, Some(w))).unwrap();
    }
    std::thread::sleep(std::time::Duration::from_millis(5));
    let t0 = Instant::now();
    let requeued = engine.expire_leases();
    let expire_wall = t0.elapsed().as_secs_f64();
    assert_eq!(requeued as u64, k);
    println!(
        "\nmass preemption: {k} leases requeued in {} ({:.0} trials/s)",
        fmt_duration(expire_wall),
        k as f64 / expire_wall
    );

    let mut out = Value::obj();
    out.set("bench", "fleet")
        .set("trials", trials)
        .set("workers", N_WORKER_THREADS)
        .set("studies", N_STUDIES)
        .set("rows", Value::Arr(rows))
        .set("requeue", {
            let mut r = Value::obj();
            r.set("leases", k)
                .set("wall_s", expire_wall)
                .set("requeues_per_s", k as f64 / expire_wall);
            Value::Obj(r)
        });
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_fleet.json");
    std::fs::write(&json_path, Value::Obj(out).to_pretty()).unwrap();
    println!("wrote {}", json_path.display());
}

//! E3 — the §4 campaign claims: "dozens of optimization studies with
//! hundreds of trials on each study from more than twenty concurrent and
//! diverse computing nodes".
//!
//! 24 studies × 100+ trials from 24 nodes across 4 site profiles run
//! against one server; reports per-study completion, site attribution,
//! aggregate throughput, and server API latency percentiles under the
//! full campaign load.
//!
//! Run: `cargo bench --bench campaign`

use hopaas::bench::fmt_duration;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::{Objective, ALL};
use hopaas::worker::Campaign;

fn main() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .unwrap();
    let addr = server.addr();

    let n_studies = 24usize;
    let trials_per_study = 100u64;
    let nodes_per_study = 24usize;

    println!(
        "\nE3: {n_studies} studies × {trials_per_study} trials × {nodes_per_study} nodes (4 site profiles)\n"
    );
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n_studies)
        .map(|i| {
            let objective: Objective = ALL[i % ALL.len()];
            std::thread::spawn(move || {
                let mut c = Campaign::new(addr, "x".into(), objective);
                c.study_name = format!("e3-{i}-{}", objective.name());
                c.n_nodes = nodes_per_study;
                c.max_trials = trials_per_study;
                c.steps_per_trial = 10;
                c.step_cost_us = 100;
                c.seed = i as u64;
                c.run().unwrap()
            })
        })
        .collect();

    let mut total = (0u64, 0u64, 0u64); // completed, pruned, preempted
    let mut by_site: Vec<(String, u64)> = Vec::new();
    for h in handles {
        let r = h.join().unwrap();
        total.0 += r.completed;
        total.1 += r.pruned;
        total.2 += r.preempted;
        for (site, n) in r.by_site {
            match by_site.iter_mut().find(|(s, _)| *s == site) {
                Some((_, t)) => *t += n,
                None => by_site.push((site, n)),
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let trials = total.0 + total.1 + total.2;

    println!("studies:      {n_studies}");
    println!("trials:       {trials} ({} completed, {} pruned, {} preempted)", total.0, total.1, total.2);
    println!("wall:         {wall:.1}s  ->  {:.0} trials/s aggregate", trials as f64 / wall);
    println!("\nper-site completions (diverse concurrent nodes):");
    by_site.sort();
    for (site, n) in &by_site {
        println!("  {site:>16}: {n}");
    }

    // Server-side view + API latency under campaign load.
    let studies = server.engine.studies_json();
    println!("\nserver sees {} studies", studies.as_arr().unwrap().len());
    let m = &server.engine.metrics;
    println!(
        "server API latency under load: ask p50/p95/p99 = {} / {} / {}",
        fmt_duration(m.ask_latency.quantile(0.5)),
        fmt_duration(m.ask_latency.quantile(0.95)),
        fmt_duration(m.ask_latency.quantile(0.99)),
    );
    println!(
        "                              tell p50/p99 = {} / {}",
        fmt_duration(m.tell_latency.quantile(0.5)),
        fmt_duration(m.tell_latency.quantile(0.99)),
    );
    println!(
        "asks={} tells={} prunes(decided)={}",
        m.ask_total.get(),
        m.tell_total.get(),
        m.prune_decisions.get()
    );
    assert!(
        studies.as_arr().unwrap().len() == n_studies,
        "every study definition mapped to exactly one study"
    );
    server.stop();
}

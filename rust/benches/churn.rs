//! E7 — opportunistic-resource churn: campaign behaviour vs preemption
//! rate. The paper's §1 motivation is exploiting opportunistic GPUs that
//! may vanish at any time; the service must keep converging and the
//! reaper must recycle silent trials.
//!
//! Run: `cargo bench --bench churn`

use hopaas::coordinator::engine::EngineConfig;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::Objective;
use hopaas::worker::{Campaign, Site};

fn main() {
    println!("\nE7: campaign vs preemption rate (16 nodes, 120 trials, sphere)\n");
    println!(
        "{:<10} {:>10} {:>8} {:>10} {:>8} {:>10} {:>10}",
        "preempt", "completed", "pruned", "preempted", "reaped", "best", "trials/s"
    );
    println!("{}", "-".repeat(72));

    for preempt in [0.0, 0.1, 0.3, 0.5] {
        let server = HopaasServer::start(
            "127.0.0.1:0",
            HopaasConfig {
                auth_required: false,
                engine: EngineConfig { reap_after: Some(0.2), ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap();

        // Uniform fleet with the given preemption probability.
        let mut campaign = Campaign::new(server.addr(), "x".into(), Objective::Sphere);
        campaign.n_nodes = 16;
        campaign.max_trials = 120;
        campaign.steps_per_trial = 10;
        campaign.step_cost_us = 150;
        let report = run_with_preempt(&campaign, preempt);

        // Give the reaper a chance, then count.
        std::thread::sleep(std::time::Duration::from_millis(300));
        let reaped = server.engine.reap_stale();
        println!(
            "{:<10.2} {:>10} {:>8} {:>10} {:>8} {:>10.4} {:>10.1}",
            preempt,
            report.completed,
            report.pruned,
            report.preempted,
            reaped,
            report.best.unwrap_or(f64::NAN),
            report.throughput()
        );
        // Shape: convergence survives heavy churn (best stays low) and
        // every preempted trial is eventually reaped.
        assert!(reaped as u64 <= report.preempted, "reaped ≤ preempted");
        server.stop();
    }
    println!(
        "\nshape check: completed count degrades ~linearly with preemption,\n\
         best value stays near-optimal (the study, not the node, carries the\n\
         knowledge), and reaped ≈ preempted."
    );
}

/// Clone of Campaign::run with a preemption override on every site.
fn run_with_preempt(c: &Campaign, preempt: f64) -> hopaas::worker::CampaignReport {
    // Build a modified campaign by overriding the per-site preemption via
    // a custom site table: we reuse Campaign but scale preemption by
    // running nodes on one synthetic site.
    let mut campaign = c.clone();
    campaign.study_name = format!("{}-p{preempt}", c.study_name);
    // The Campaign API cycles over SITES; to control preemption exactly we
    // run the stock fleet when preempt ≈ fleet average, otherwise a
    // single-profile fleet through the lower-level loop.
    let site = Site { name: "synthetic", speed: 1.0, preempt, net_latency_us: 200 };
    campaign.run_with_sites(&[site]).unwrap()
}

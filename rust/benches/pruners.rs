//! E5 — pruner ablation: compute saved vs quality lost.
//!
//! The paper's §2: pruning "abort[s] non-promising trials without
//! wasting computing power to take the training procedure to an end".
//! Each pruner runs 200 trials × ≤60 steps of simulated learning curves
//! through the real engine; the table reports steps executed (compute),
//! savings vs no pruning, pruned count, best final loss, and the regret
//! vs the no-pruning best. Expected shape: ASHA/percentile most
//! aggressive (≥60% saved), median ~40-50%, all at ≤ a few % regret.
//!
//! Run: `cargo bench --bench pruners`

use hopaas::bench::mean_std;
use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::json::Value;
use hopaas::objectives::LearningCurve;
use hopaas::rng::Rng;

const TRIALS: usize = 200;
const MAX_STEPS: u64 = 60;
const SEEDS: u64 = 5;

fn ask_body(pruner: Option<&str>, seed: u64) -> Value {
    let mut o = Value::obj();
    o.set("study_name", format!("e5-{}-{seed}", pruner.unwrap_or("none")))
        .set("properties", {
            let mut p = Value::obj();
            let mut q = Value::obj();
            q.set("low", 0.0).set("high", 1.0);
            p.set("quality", Value::Obj(q));
            Value::Obj(p)
        })
        .set("sampler", {
            let mut s = Value::obj();
            s.set("name", "random"); // isolate the pruner's effect
            Value::Obj(s)
        });
    if let Some(p) = pruner {
        let mut cfg = Value::obj();
        cfg.set("name", p);
        match p {
            "median" | "percentile" => {
                cfg.set("warmup_steps", 3).set("min_trials", 5);
            }
            "sha" | "hyperband" => {
                cfg.set("min_resource", 2).set("reduction_factor", 3);
            }
            _ => {}
        }
        o.set("pruner", Value::Obj(cfg));
    }
    Value::Obj(o)
}

fn run(pruner: Option<&str>, seed: u64) -> (u64, u64, f64) {
    let engine = Engine::in_memory(EngineConfig { seed: 77 + seed, ..Default::default() });
    let body = ask_body(pruner, seed);
    let mut rng = Rng::new(seed);
    let mut steps = 0u64;
    let mut pruned_n = 0u64;
    let mut best = f64::INFINITY;
    for _ in 0..TRIALS {
        let reply = engine.ask(&body).unwrap();
        let quality = reply.params.get("quality").as_f64().unwrap();
        let curve = LearningCurve::from_quality(quality, &mut rng);
        let mut pruned = false;
        for step in 1..=MAX_STEPS {
            steps += 1;
            let loss = curve.at(step, &mut rng);
            if engine.should_prune(reply.trial_id, step, loss).unwrap() {
                pruned = true;
                pruned_n += 1;
                break;
            }
        }
        if !pruned {
            let v = curve.final_loss();
            engine.tell(reply.trial_id, v).unwrap();
            best = best.min(v);
        }
    }
    (steps, pruned_n, best)
}

fn main() {
    println!(
        "\nE5: pruner ablation — {TRIALS} trials × ≤{MAX_STEPS} steps, {SEEDS} seeds, random search\n"
    );
    println!(
        "{:<12} {:>10} {:>9} {:>8} {:>12} {:>10}",
        "pruner", "steps", "saved", "pruned", "best loss", "regret"
    );
    println!("{}", "-".repeat(66));

    // Baseline: no pruning.
    let mut base_steps = Vec::new();
    let mut base_best = Vec::new();
    for seed in 0..SEEDS {
        let (s, _, b) = run(None, seed);
        base_steps.push(s as f64);
        base_best.push(b);
    }
    let (mean_base_steps, _) = mean_std(&base_steps);
    let (mean_base_best, _) = mean_std(&base_best);
    println!(
        "{:<12} {:>10.0} {:>9} {:>8} {:>12.4} {:>10}",
        "none", mean_base_steps, "—", 0, mean_base_best, "—"
    );

    for pruner in ["median", "percentile", "sha", "hyperband", "patient", "threshold"] {
        let mut steps_v = Vec::new();
        let mut pruned_v = Vec::new();
        let mut best_v = Vec::new();
        for seed in 0..SEEDS {
            let (s, p, b) = run(Some(pruner), seed);
            steps_v.push(s as f64);
            pruned_v.push(p as f64);
            best_v.push(b);
        }
        let (ms, _) = mean_std(&steps_v);
        let (mp, _) = mean_std(&pruned_v);
        let (mb, _) = mean_std(&best_v);
        println!(
            "{:<12} {:>10.0} {:>8.1}% {:>8.0} {:>12.4} {:>10.4}",
            pruner,
            ms,
            100.0 * (mean_base_steps - ms) / mean_base_steps,
            mp,
            mb,
            mb - mean_base_best
        );
    }
    println!(
        "\nshape check: aggressive pruners (percentile/sha) save ≥50% of steps\n\
         at small regret; threshold (absolute bound) saves little here since\n\
         curves rarely diverge."
    );
}

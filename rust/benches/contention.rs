//! Shard-contention benchmark: multi-threaded, multi-study ask/tell
//! throughput at 1 / 4 / 16 shards.
//!
//! The seed engine serialized every mutation on one global mutex; the
//! sharded engine routes each study to `fnv1a(key) % N` shards with
//! independent locks. With T threads driving T distinct studies, the
//! 1-shard row is the single-lock baseline and the speedup at ≥4
//! shards is the contention the refactor removed. TPE is used so each
//! ask carries a real surrogate refit plus a lock-held history
//! snapshot — the regime of a §4 campaign in progress.
//!
//! Run: `cargo bench --bench contention`

use hopaas::bench::{fmt_duration, Table};
use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::json::{parse, Value};
use std::sync::Arc;

const N_THREADS: usize = 8;
const TRIALS_PER_THREAD: usize = 200;
/// Pre-seeded history per study, so TPE is past its startup phase and
/// every ask pays for a KDE refit over real observations.
const WARM_TRIALS: usize = 64;

fn ask_body(study: usize) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "contention-{study}",
        "properties": {{
            "lr": {{"low": 1e-5, "high": 1e-1, "type": "loguniform"}},
            "x": {{"low": 0.0, "high": 1.0}},
            "y": {{"low": 0.0, "high": 1.0}}
        }},
        "direction": "minimize",
        "sampler": {{"name": "tpe"}}
    }}"#
    ))
    .unwrap()
}

fn objective(study: usize, number: u64) -> f64 {
    ((study as f64 + 1.0) * 0.61 + number as f64 * 0.17).sin().abs()
}

/// Run the workload on an engine with `n_shards`; returns aggregate
/// (ask+tell) operations per second.
fn run(n_shards: usize) -> f64 {
    let engine = Arc::new(Engine::in_memory(EngineConfig {
        n_shards,
        ..Default::default()
    }));
    // Warm every study sequentially (identical across shard counts).
    for t in 0..N_THREADS {
        let body = ask_body(t);
        for _ in 0..WARM_TRIALS {
            let r = engine.ask(&body).unwrap();
            engine.tell(r.trial_id, objective(t, r.trial_number)).unwrap();
        }
    }

    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..N_THREADS)
        .map(|t| {
            let engine = engine.clone();
            std::thread::spawn(move || {
                let body = ask_body(t);
                for _ in 0..TRIALS_PER_THREAD {
                    let r = engine.ask(&body).unwrap();
                    engine
                        .tell(r.trial_id, objective(t, r.trial_number))
                        .unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let ops = (N_THREADS * TRIALS_PER_THREAD * 2) as f64;
    ops / wall
}

fn main() {
    println!(
        "\ncontention: {N_THREADS} threads × {N_THREADS} studies × {TRIALS_PER_THREAD} trials (ask+tell, TPE, warm history {WARM_TRIALS})\n"
    );
    let table = Table::new(
        &["shards", "ops/s", "mean op", "speedup vs 1 shard"],
        &[8, 12, 12, 20],
    );
    let mut baseline = 0.0;
    let mut best_speedup: f64 = 0.0;
    for &shards in &[1usize, 4, 16] {
        let ops = run(shards);
        if shards == 1 {
            baseline = ops;
        }
        let speedup = ops / baseline;
        best_speedup = best_speedup.max(speedup);
        table.row(&[
            &shards.to_string(),
            &format!("{ops:.0}"),
            &fmt_duration(1.0 / ops),
            &format!("{speedup:.2}x"),
        ]);
    }
    println!(
        "\nmax multi-study speedup over the single-lock baseline: {best_speedup:.2}x"
    );
}

//! T1 — per-API latency and throughput of the Table 1 surface.
//!
//! Regenerates the operational content of the paper's Table 1: each API
//! measured over real HTTP against a warm server at several client
//! concurrencies, with a 500-trial TPE history behind `ask` (the regime
//! of a §4 campaign in progress).
//!
//! Run: `cargo bench --bench api_latency`

use hopaas::bench::{fmt_duration, Samples};
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::http::Client;
use hopaas::json::{parse, Value};
use std::sync::{Arc, Mutex};

fn ask_body() -> Value {
    parse(
        r#"{
        "study_name": "bench",
        "properties": {
            "lr": {"low": 1e-5, "high": 1e-1, "type": "loguniform"},
            "x": {"low": 0.0, "high": 1.0},
            "opt": ["adam", "rmsprop"]
        },
        "sampler": {"name": "tpe"},
        "pruner": {"name": "median"}
    }"#,
    )
    .unwrap()
}

fn row(api: &str, conc: usize, s: &Samples, wall: f64) {
    println!(
        "{:<14} {:>5} {:>10} {:>10} {:>10} {:>12.0}",
        api,
        conc,
        fmt_duration(s.quantile(0.5)),
        fmt_duration(s.quantile(0.95)),
        fmt_duration(s.quantile(0.99)),
        s.len() as f64 / wall
    );
}

/// Run `per_thread` iterations on `conc` threads (own client + scratch).
fn run<F>(addr: std::net::SocketAddr, conc: usize, per_thread: usize, f: F) -> (Samples, f64)
where
    F: Fn(&mut Client, &mut Vec<u64>) + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..conc)
        .map(|_| {
            let f = f.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                // Generous socket timeout: under heavy oversubscription on
                // small hosts the tail can exceed the 30s default.
                c.set_timeout(std::time::Duration::from_secs(300));
                let mut scratch: Vec<u64> = Vec::new();
                let mut s = Samples::new();
                for _ in 0..per_thread {
                    s.time(|| f(&mut c, &mut scratch));
                }
                s
            })
        })
        .collect();
    let mut all = Samples::new();
    for h in handles {
        all.merge(&h.join().unwrap());
    }
    (all, t0.elapsed().as_secs_f64())
}

fn main() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: true, ..Default::default() },
    )
    .unwrap();
    let tok = Arc::new(server.bootstrap_token.clone());
    let addr = server.addr();

    // Seed 500 completed trials.
    {
        let mut c = Client::connect(addr).unwrap();
        for i in 0..500 {
            let ask = c
                .post_json(&format!("/api/ask/{tok}"), &ask_body())
                .unwrap()
                .json_body()
                .unwrap();
            let id = ask.get("trial_id").as_u64().unwrap();
            let mut rep = Value::obj();
            rep.set("trial_id", id).set("step", 1u64).set("value", (i % 17) as f64);
            c.post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep)).unwrap();
            let mut tell = Value::obj();
            tell.set("trial_id", id).set("value", (i % 17) as f64);
            c.post_json(&format!("/api/tell/{tok}"), &Value::Obj(tell)).unwrap();
        }
    }

    println!("\nT1: API latency/throughput (warm server, 500-trial TPE history)\n");
    println!(
        "{:<14} {:>5} {:>10} {:>10} {:>10} {:>12}",
        "api", "conc", "p50", "p95", "p99", "req/s"
    );
    println!("{}", "-".repeat(66));

    for conc in [1usize, 8, 32, 64] {
        // version: GET probe.
        let (s, w) = run(addr, conc, 400, |c, _| {
            assert_eq!(c.get("/api/version").unwrap().status, 200);
        });
        row("version", conc, &s, w);

        // ask: study join + TPE suggest.
        let (s, w) = run(addr, conc, 120, {
            let tok = tok.clone();
            move |c, _| {
                let r = c.post_json(&format!("/api/ask/{tok}"), &ask_body()).unwrap();
                assert_eq!(r.status, 200);
            }
        });
        row("ask", conc, &s, w);

        // should_prune: one running trial per thread, increasing steps.
        let (s, w) = run(addr, conc, 120, {
            let tok = tok.clone();
            move |c, state| {
                if state.is_empty() {
                    // One untimed ask per thread to get a trial id; the
                    // timed region is the prune call only (first call
                    // includes this setup — amortized over 120 iters).
                    let ask = c
                        .post_json(&format!("/api/ask/{tok}"), &ask_body())
                        .unwrap()
                        .json_body()
                        .unwrap();
                    state.push(ask.get("trial_id").as_u64().unwrap());
                    state.push(0); // step counter
                }
                state[1] += 1;
                let mut rep = Value::obj();
                rep.set("trial_id", state[0]).set("step", state[1]).set("value", 1.0);
                let r = c
                    .post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep))
                    .unwrap();
                assert_eq!(r.status, 200);
            }
        });
        row("should_prune", conc, &s, w);

        // tell: pre-created trials, timed region is the tell only.
        let ids: Vec<u64> = {
            let mut c = Client::connect(addr).unwrap();
            (0..conc * 120)
                .map(|_| {
                    c.post_json(&format!("/api/ask/{tok}"), &ask_body())
                        .unwrap()
                        .json_body()
                        .unwrap()
                        .get("trial_id")
                        .as_u64()
                        .unwrap()
                })
                .collect()
        };
        let ids = Arc::new(Mutex::new(ids));
        let (s, w) = run(addr, conc, 120, {
            let tok = tok.clone();
            let ids = ids.clone();
            move |c, _| {
                let id = ids.lock().unwrap().pop().unwrap();
                let mut tell = Value::obj();
                tell.set("trial_id", id).set("value", 2.0);
                let r = c.post_json(&format!("/api/tell/{tok}"), &Value::Obj(tell)).unwrap();
                assert_eq!(r.status, 200);
            }
        });
        row("tell", conc, &s, w);
        println!();
    }

    // Auth-rejection fast path (the 401 the paper's token scheme must
    // serve cheaply under junk traffic).
    let (s, w) = run(addr, 8, 300, |c, _| {
        assert_eq!(c.post_json("/api/ask/garbage", &ask_body()).unwrap().status, 401);
    });
    row("ask(401)", 8, &s, w);

    server.stop();
}

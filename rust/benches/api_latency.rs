//! T1 — per-API latency and throughput of the Table 1 surface.
//!
//! Regenerates the operational content of the paper's Table 1: each API
//! measured over real HTTP against a warm server at several client
//! concurrencies, with a 500-trial TPE history behind `ask` (the regime
//! of a §4 campaign in progress).
//!
//! A second phase measures the **mixed read/write** regime of the
//! materialized-view read path: K dashboard viewers (long-polling the
//! event feed and paging trials) against M fleet writers on a 4-shard
//! engine, reporting the write-latency regression the viewers cost.
//! Because views are Arc-swapped snapshots and parked long-polls leave
//! the worker pool, the regression should be small.
//!
//! Results are printed as tables and written to `BENCH_api.json`.
//!
//! Run: `cargo bench --bench api_latency [-- --viewers 1000 --writers 8]`

use hopaas::bench::{fmt_duration, Samples};
use hopaas::config::Args;
use hopaas::coordinator::engine::EngineConfig;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::http::Client;
use hopaas::json::{parse, Value};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn ask_body() -> Value {
    parse(
        r#"{
        "study_name": "bench",
        "properties": {
            "lr": {"low": 1e-5, "high": 1e-1, "type": "loguniform"},
            "x": {"low": 0.0, "high": 1.0},
            "opt": ["adam", "rmsprop"]
        },
        "sampler": {"name": "tpe"},
        "pruner": {"name": "median"}
    }"#,
    )
    .unwrap()
}

/// Print one result row and return it as a JSON record.
fn row(api: &str, conc: usize, s: &Samples, wall: f64) -> Value {
    println!(
        "{:<14} {:>5} {:>10} {:>10} {:>10} {:>12.0}",
        api,
        conc,
        fmt_duration(s.quantile(0.5)),
        fmt_duration(s.quantile(0.95)),
        fmt_duration(s.quantile(0.99)),
        s.len() as f64 / wall
    );
    let mut r = Value::obj();
    r.set("api", api)
        .set("conc", conc)
        .set("p50_s", s.quantile(0.5))
        .set("p95_s", s.quantile(0.95))
        .set("p99_s", s.quantile(0.99))
        .set("req_per_s", s.len() as f64 / wall);
    Value::Obj(r)
}

/// Run `per_thread` iterations on `conc` threads (own client + scratch).
fn run<F>(addr: std::net::SocketAddr, conc: usize, per_thread: usize, f: F) -> (Samples, f64)
where
    F: Fn(&mut Client, &mut Vec<u64>) + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..conc)
        .map(|_| {
            let f = f.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                // Generous socket timeout: under heavy oversubscription on
                // small hosts the tail can exceed the 30s default.
                c.set_timeout(std::time::Duration::from_secs(300));
                let mut scratch: Vec<u64> = Vec::new();
                let mut s = Samples::new();
                for _ in 0..per_thread {
                    s.time(|| f(&mut c, &mut scratch));
                }
                s
            })
        })
        .collect();
    let mut all = Samples::new();
    for h in handles {
        all.merge(&h.join().unwrap());
    }
    (all, t0.elapsed().as_secs_f64())
}

/// Seed `n` completed trials through the public API; returns the study id.
fn seed(addr: std::net::SocketAddr, tok: &str, n: usize) -> u64 {
    let mut c = Client::connect(addr).unwrap();
    let mut sid = 0;
    for i in 0..n {
        let ask = c
            .post_json(&format!("/api/ask/{tok}"), &ask_body())
            .unwrap()
            .json_body()
            .unwrap();
        sid = ask.get("study_id").as_u64().unwrap();
        let id = ask.get("trial_id").as_u64().unwrap();
        let mut rep = Value::obj();
        rep.set("trial_id", id).set("step", 1u64).set("value", (i % 17) as f64);
        c.post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep)).unwrap();
        let mut tell = Value::obj();
        tell.set("trial_id", id).set("value", (i % 17) as f64);
        c.post_json(&format!("/api/tell/{tok}"), &Value::Obj(tell)).unwrap();
    }
    sid
}

/// Pre-create trials so a tell phase times only the tell.
fn pre_ask(addr: std::net::SocketAddr, tok: &str, n: usize) -> Vec<u64> {
    let mut c = Client::connect(addr).unwrap();
    (0..n)
        .map(|_| {
            c.post_json(&format!("/api/ask/{tok}"), &ask_body())
                .unwrap()
                .json_body()
                .unwrap()
                .get("trial_id")
                .as_u64()
                .unwrap()
        })
        .collect()
}

/// Ask p99 over a warm 500-trial server at the given trace capacity.
fn ask_p99(trace_capacity: usize, conc: usize, iters: usize) -> (f64, f64) {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig {
            auth_required: false,
            engine: EngineConfig { trace_capacity, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    seed(addr, "x", 500);
    let (s, w) = run(addr, conc, iters, |c, _| {
        let r = c.post_json("/api/ask/x", &ask_body()).unwrap();
        assert_eq!(r.status, 200);
    });
    server.stop();
    (s.quantile(0.99), s.len() as f64 / w)
}

/// Tracing overhead: ask p99 with the tracer at its defaults vs fully
/// off (`--trace-capacity 0`). The trace subsystem is designed to stay
/// off the hot path — fixed-capacity striped ring, no allocation on
/// record — so the acceptance gate is on-p99 within 5% of off-p99
/// (noise allowing; the JSON carries the raw numbers either way).
fn obs_overhead() -> Value {
    let conc = 8usize;
    let iters = 150usize;
    let (off_p99, off_rps) = ask_p99(0, conc, iters);
    let (on_p99, on_rps) = ask_p99(2048, conc, iters);
    let ratio = on_p99 / off_p99.max(1e-9);
    println!(
        "\nobs overhead ({conc} writers): ask p99 tracing-off {} vs tracing-on {} ({ratio:.3}x)",
        fmt_duration(off_p99),
        fmt_duration(on_p99),
    );
    let mut o = Value::obj();
    o.set("conc", conc)
        .set("iters", iters)
        .set("ask_p99_off_s", off_p99)
        .set("ask_p99_on_s", on_p99)
        .set("ask_p99_ratio", ratio)
        .set("req_per_s_off", off_rps)
        .set("req_per_s_on", on_rps);
    Value::Obj(o)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let mut rows: Vec<Value> = Vec::new();

    // `--only obs`: just the tracing-overhead phase (the CI
    // observability job runs this against every push).
    if args.get("only") == Some("obs") {
        let obs = obs_overhead();
        let mut out = Value::obj();
        out.set("bench", "api").set("obs", obs);
        let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_api.json");
        std::fs::write(&json_path, Value::Obj(out).to_pretty()).unwrap();
        println!("wrote {}", json_path.display());
        return;
    }

    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: true, ..Default::default() },
    )
    .unwrap();
    let tok = Arc::new(server.bootstrap_token.clone());
    let addr = server.addr();

    // Seed 500 completed trials.
    seed(addr, &tok, 500);

    println!("\nT1: API latency/throughput (warm server, 500-trial TPE history)\n");
    println!(
        "{:<14} {:>5} {:>10} {:>10} {:>10} {:>12}",
        "api", "conc", "p50", "p95", "p99", "req/s"
    );
    println!("{}", "-".repeat(66));

    for conc in [1usize, 8, 32, 64] {
        // version: GET probe.
        let (s, w) = run(addr, conc, 400, |c, _| {
            assert_eq!(c.get("/api/version").unwrap().status, 200);
        });
        rows.push(row("version", conc, &s, w));

        // ask: study join + TPE suggest.
        let (s, w) = run(addr, conc, 120, {
            let tok = tok.clone();
            move |c, _| {
                let r = c.post_json(&format!("/api/ask/{tok}"), &ask_body()).unwrap();
                assert_eq!(r.status, 200);
            }
        });
        rows.push(row("ask", conc, &s, w));

        // should_prune: one running trial per thread, increasing steps.
        let (s, w) = run(addr, conc, 120, {
            let tok = tok.clone();
            move |c, state| {
                if state.is_empty() {
                    // One untimed ask per thread to get a trial id; the
                    // timed region is the prune call only (first call
                    // includes this setup — amortized over 120 iters).
                    let ask = c
                        .post_json(&format!("/api/ask/{tok}"), &ask_body())
                        .unwrap()
                        .json_body()
                        .unwrap();
                    state.push(ask.get("trial_id").as_u64().unwrap());
                    state.push(0); // step counter
                }
                state[1] += 1;
                let mut rep = Value::obj();
                rep.set("trial_id", state[0]).set("step", state[1]).set("value", 1.0);
                let r = c
                    .post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep))
                    .unwrap();
                assert_eq!(r.status, 200);
            }
        });
        rows.push(row("should_prune", conc, &s, w));

        // tell: pre-created trials, timed region is the tell only.
        let ids = Arc::new(Mutex::new(pre_ask(addr, &tok, conc * 120)));
        let (s, w) = run(addr, conc, 120, {
            let tok = tok.clone();
            let ids = ids.clone();
            move |c, _| {
                let id = ids.lock().unwrap().pop().unwrap();
                let mut tell = Value::obj();
                tell.set("trial_id", id).set("value", 2.0);
                let r = c.post_json(&format!("/api/tell/{tok}"), &Value::Obj(tell)).unwrap();
                assert_eq!(r.status, 200);
            }
        });
        rows.push(row("tell", conc, &s, w));
        println!();
    }

    // Auth-rejection fast path (the 401 the paper's token scheme must
    // serve cheaply under junk traffic).
    let (s, w) = run(addr, 8, 300, |c, _| {
        assert_eq!(c.post_json("/api/ask/garbage", &ask_body()).unwrap().status, 401);
    });
    rows.push(row("ask(401)", 8, &s, w));
    server.stop();

    // ---- Mixed read/write: K viewers vs M writers, 4-shard engine ----
    //
    // Dashboard viewers long-poll the event feed (parking on the pump,
    // not on a worker thread) and page trials/best on wakes, while fleet
    // writers keep asking/telling. The write p99 is measured with and
    // without the viewer fleet; the ratio is the read-path's cost.
    let viewers = args.get_u64("viewers", 1000) as usize;
    let writers = args.get_u64("writers", 8) as usize;
    let iters = 60usize;

    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig {
            auth_required: false,
            engine: EngineConfig { n_shards: 4, ..Default::default() },
            events_poll_timeout: Duration::from_secs(2),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    let sid = seed(addr, "x", 500);

    println!("\nmixed read/write: {viewers} viewers + {writers} writers (4 shards)\n");
    println!(
        "{:<14} {:>5} {:>10} {:>10} {:>10} {:>12}",
        "api", "conc", "p50", "p95", "p99", "req/s"
    );
    println!("{}", "-".repeat(66));

    let ask_op = |c: &mut Client, _: &mut Vec<u64>| {
        let r = c.post_json("/api/ask/x", &ask_body()).unwrap();
        assert_eq!(r.status, 200);
    };

    // Baseline: writers alone.
    let (ask_base, w) = run(addr, writers, iters, ask_op);
    rows.push(row("mixed:ask(0v)", writers, &ask_base, w));
    let ids = Arc::new(Mutex::new(pre_ask(addr, "x", writers * iters)));
    let (tell_base, w) = run(addr, writers, iters, {
        let ids = ids.clone();
        move |c, _| {
            let id = ids.lock().unwrap().pop().unwrap();
            let mut tell = Value::obj();
            tell.set("trial_id", id).set("value", 2.0);
            assert_eq!(c.post_json("/api/tell/x", &Value::Obj(tell)).unwrap().status, 200);
        }
    });
    rows.push(row("mixed:tell(0v)", writers, &tell_base, w));

    // Spin up the viewer fleet: each long-polls the bench study's feed
    // and, every few wakes, reads one trial page plus the incumbent.
    let stop = Arc::new(AtomicBool::new(false));
    let pages = Arc::new(AtomicU64::new(0));
    let viewer_handles: Vec<_> = (0..viewers)
        .map(|i| {
            let stop = stop.clone();
            let pages = pages.clone();
            std::thread::spawn(move || {
                // Stagger connects so the accept queue never overflows.
                std::thread::sleep(Duration::from_millis((i % 256) as u64));
                let Ok(mut c) = Client::connect(addr) else { return };
                c.set_timeout(Duration::from_secs(10));
                let mut watermark = 0u64;
                let mut wakes = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let Ok(r) =
                        c.get(&format!("/api/studies/{sid}/events?since={watermark}&timeout=0.5"))
                    else {
                        return;
                    };
                    let Ok(v) = r.json_body() else { return };
                    if let Some(wm) = v.get("watermark").as_u64() {
                        watermark = wm;
                    }
                    pages.fetch_add(1, Ordering::Relaxed);
                    wakes += 1;
                    if wakes % 4 == 0 {
                        if c.get(&format!("/api/studies/{sid}/trials?limit=100")).is_err() {
                            return;
                        }
                        if c.get(&format!("/api/studies/{sid}/best")).is_err() {
                            return;
                        }
                        pages.fetch_add(2, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    // Let the fleet connect and park before measuring.
    std::thread::sleep(Duration::from_millis(1000));

    let (ask_mixed, w) = run(addr, writers, iters, ask_op);
    rows.push(row(&format!("mixed:ask({viewers}v)"), writers, &ask_mixed, w));
    let ids = Arc::new(Mutex::new(pre_ask(addr, "x", writers * iters)));
    let (tell_mixed, w) = run(addr, writers, iters, {
        let ids = ids.clone();
        move |c, _| {
            let id = ids.lock().unwrap().pop().unwrap();
            let mut tell = Value::obj();
            tell.set("trial_id", id).set("value", 2.0);
            assert_eq!(c.post_json("/api/tell/x", &Value::Obj(tell)).unwrap().status, 200);
        }
    });
    rows.push(row(&format!("mixed:tell({viewers}v)"), writers, &tell_mixed, w));

    stop.store(true, Ordering::Relaxed);
    for h in viewer_handles {
        let _ = h.join();
    }
    let viewer_pages = pages.load(Ordering::Relaxed);
    let ask_ratio = ask_mixed.quantile(0.99) / ask_base.quantile(0.99).max(1e-9);
    let tell_ratio = tell_mixed.quantile(0.99) / tell_base.quantile(0.99).max(1e-9);
    println!(
        "\nviewer pages served: {viewer_pages}; p99 regression with viewers: \
         ask {ask_ratio:.2}x, tell {tell_ratio:.2}x"
    );
    server.stop();

    let mut mixed = Value::obj();
    mixed
        .set("viewers", viewers)
        .set("writers", writers)
        .set("shards", 4)
        .set("viewer_pages", viewer_pages)
        .set("ask_p99_base_s", ask_base.quantile(0.99))
        .set("ask_p99_mixed_s", ask_mixed.quantile(0.99))
        .set("ask_p99_ratio", ask_ratio)
        .set("tell_p99_base_s", tell_base.quantile(0.99))
        .set("tell_p99_mixed_s", tell_mixed.quantile(0.99))
        .set("tell_p99_ratio", tell_ratio);

    let obs = obs_overhead();

    let mut out = Value::obj();
    out.set("bench", "api")
        .set("rows", Value::Arr(rows))
        .set("mixed", Value::Obj(mixed))
        .set("obs", obs);
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_api.json");
    std::fs::write(&json_path, Value::Obj(out).to_pretty()).unwrap();
    println!("wrote {}", json_path.display());
}

//! Replication benchmark: (1) catch-up throughput — drain a pre-built
//! replication stream into a cold follower, at 1 / 4 / 16 shards; and
//! (2) steady-state lag — a live applier thread tails the primary
//! while writers drive ask/tell load, sampling the follower's seq lag
//! and timing convergence after the writers stop.
//!
//! Both phases run in-process over `LocalTransport` (no sockets), so
//! the numbers isolate the replication machinery itself: fetch
//! batching, follower WAL append + fsync, and incremental view
//! rebuild. Results are printed as tables and written to
//! `BENCH_replication.json` at the repository root.
//!
//! Run: `cargo bench --bench replication [-- --trials N --seconds S]`

use hopaas::bench::{fmt_duration, Table};
use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::coordinator::replica::{LocalTransport, ReplicaApplier};
use hopaas::json::{parse, Value};
use hopaas::store::ReplFetch;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const N_STUDIES: usize = 8;

fn ask_body(study: usize) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "repl-{study}",
        "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
        "direction": "minimize",
        "sampler": {{"name": "random"}}
    }}"#
    ))
    .unwrap()
}

/// Scratch directory (best-effort cleanup).
struct Scratch(std::path::PathBuf);

impl Scratch {
    fn new(tag: &str) -> Scratch {
        let p = std::env::temp_dir()
            .join(format!("hopaas-bench-repl-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        Scratch(p)
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn engine_config(shards: usize, follower: bool) -> EngineConfig {
    EngineConfig {
        n_shards: shards,
        follower,
        // Never compact: the stream must stay fetchable from seq 0.
        compact_after: u64::MAX,
        repl_buffer: 1 << 21,
        ..Default::default()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let arg = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse::<u64>().ok())
    };
    let trials = arg("--trials").unwrap_or(8_000);
    let seconds = arg("--seconds").unwrap_or(2);

    // ---- Phase 1: cold-follower catch-up throughput --------------------
    println!("\ncatch-up: {trials} told trials per shard count, {N_STUDIES} studies\n");
    let table = Table::new(
        &["shards", "records", "drain wall", "records/s"],
        &[8, 10, 12, 12],
    );
    let mut catchup_rows: Vec<Value> = Vec::new();
    for &shards in &[1usize, 4, 16] {
        let dp = Scratch::new(&format!("cu-p-{shards}"));
        let df = Scratch::new(&format!("cu-f-{shards}"));
        let primary = Engine::open(&dp.0, engine_config(shards, false)).unwrap();
        for i in 0..trials {
            let r = primary.ask(&ask_body((i % N_STUDIES as u64) as usize)).unwrap();
            primary.tell(r.trial_id, (i % 100) as f64).unwrap();
        }
        let source = primary.repl_source().unwrap();
        let follower = Engine::open(&df.0, engine_config(shards, true)).unwrap();

        let t0 = Instant::now();
        loop {
            match source.fetch(follower.repl_next(), 4096) {
                ReplFetch::Batches { records, next: _, primary_next } => {
                    follower.apply_repl_batch(&records, primary_next).unwrap();
                }
                ReplFetch::UpToDate { next } => {
                    follower.apply_repl_batch(&[], next).unwrap();
                    break;
                }
                ReplFetch::TooOld { oldest } => panic!("stream evicted to {oldest}"),
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let records = follower.repl_next();
        table.row(&[
            &shards.to_string(),
            &records.to_string(),
            &fmt_duration(wall),
            &format!("{:.0}", records as f64 / wall),
        ]);
        let mut row = Value::obj();
        row.set("shards", shards)
            .set("records", records)
            .set("drain_wall_s", wall)
            .set("records_per_s", records as f64 / wall);
        catchup_rows.push(Value::Obj(row));
    }

    // ---- Phase 2: steady-state lag under live write load ---------------
    println!("\nsteady-state: {seconds}s of 2-thread ask/tell load per shard count\n");
    let stable = Table::new(
        &["shards", "acked/s", "lag mean", "lag p99", "lag max", "converge"],
        &[8, 10, 10, 10, 10, 12],
    );
    let mut steady_rows: Vec<Value> = Vec::new();
    for &shards in &[1usize, 4, 16] {
        let dp = Scratch::new(&format!("ss-p-{shards}"));
        let df = Scratch::new(&format!("ss-f-{shards}"));
        let primary = Arc::new(Engine::open(&dp.0, engine_config(shards, false)).unwrap());
        let follower = Arc::new(Engine::open(&df.0, engine_config(shards, true)).unwrap());
        let source = primary.repl_source().unwrap();
        let applier = ReplicaApplier::start(
            follower.clone(),
            Box::new(LocalTransport::new(source.clone(), Some(dp.0.clone()))),
            Duration::from_millis(20),
        );

        let stop = Arc::new(AtomicBool::new(false));
        let writers: Vec<_> = (0..2usize)
            .map(|w| {
                let primary = primary.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut acked = 0u64;
                    let mut i = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let study = ((i + w as u64) % N_STUDIES as u64) as usize;
                        let r = primary.ask(&ask_body(study)).unwrap();
                        primary.tell(r.trial_id, (i % 100) as f64).unwrap();
                        acked += 1;
                        i += 1;
                    }
                    acked
                })
            })
            .collect();

        // Sample the seq lag while the writers run.
        let mut lags: Vec<u64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_secs(seconds) {
            lags.push(source.next_seq().saturating_sub(follower.repl_next()));
            std::thread::sleep(Duration::from_millis(2));
        }
        stop.store(true, Ordering::Relaxed);
        let acked: u64 = writers.into_iter().map(|h| h.join().unwrap()).sum();

        // Convergence: how long until the follower holds the full tail.
        let target = source.next_seq();
        let t1 = Instant::now();
        while follower.repl_next() < target {
            assert!(
                t1.elapsed() < Duration::from_secs(30),
                "follower never converged ({} of {target})",
                follower.repl_next()
            );
            std::thread::sleep(Duration::from_micros(200));
        }
        let converge = t1.elapsed().as_secs_f64();
        applier.seal();

        lags.sort_unstable();
        let mean = lags.iter().sum::<u64>() as f64 / lags.len().max(1) as f64;
        let p99 = if lags.is_empty() { 0 } else { lags[(lags.len() - 1) * 99 / 100] };
        let max = *lags.last().unwrap_or(&0);
        let wall = t0.elapsed().as_secs_f64();
        stable.row(&[
            &shards.to_string(),
            &format!("{:.0}", acked as f64 / wall),
            &format!("{mean:.1}"),
            &p99.to_string(),
            &max.to_string(),
            &fmt_duration(converge),
        ]);
        let mut row = Value::obj();
        row.set("shards", shards)
            .set("acked_per_s", acked as f64 / wall)
            .set("lag_seq_mean", mean)
            .set("lag_seq_p99", p99)
            .set("lag_seq_max", max)
            .set("converge_s", converge);
        steady_rows.push(Value::Obj(row));
    }

    let mut out = Value::obj();
    out.set("bench", "replication")
        .set("trials", trials)
        .set("seconds", seconds)
        .set("catchup", Value::Arr(catchup_rows))
        .set("steady_state", Value::Arr(steady_rows));
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_replication.json");
    std::fs::write(&json_path, Value::Obj(out).to_pretty()).unwrap();
    println!("\nwrote {}", json_path.display());
}

//! E8 — scalability of the suggestion path and the HTTP layer.
//!
//! Questions the paper's "scalable set of Uvicorn instances" design
//! answers operationally:
//!   1. what does an ask cost cold (refit the sampler from the study
//!      history) vs cached (reuse the fit, as the engine does between
//!      tells), as the history grows?
//!   2. how does end-to-end ask throughput scale with server worker
//!      threads?
//!
//! E8a results are written to `BENCH_samplers.json` at the repository
//! root (the `bench-samplers` CI job uploads it as an artifact).
//!
//! Run: `cargo bench --bench tpe_scaling`

use hopaas::bench::{bench, fmt_duration};
use hopaas::coordinator::samplers::{make_sampler, Obs};
use hopaas::coordinator::space::{Direction, Space};
use hopaas::coordinator::study::AlgoConfig;
use hopaas::coordinator::service::{build_router, HopaasConfig, HopaasServer};
use hopaas::http::{Client, Server, ServerConfig};
use hopaas::json::{parse, Value};
use hopaas::rng::Rng;
use std::sync::Arc;

fn space() -> Space {
    Space::from_json(
        &parse(
            r#"{
            "lr": {"low": 1e-5, "high": 1e-1, "type": "loguniform"},
            "x": {"low": 0.0, "high": 1.0},
            "y": {"low": 0.0, "high": 1.0},
            "k": {"low": 1, "high": 16, "type": "int"},
            "opt": ["adam", "rmsprop", "sgd"]
        }"#,
        )
        .unwrap(),
    )
    .unwrap()
}

fn history(space: &Space, n: usize, rng: &mut Rng) -> Vec<Obs> {
    (0..n)
        .map(|i| Obs { params: space.sample(rng), value: (i % 31) as f64 })
        .collect()
}

fn main() {
    let space = space();
    let mut rng = Rng::new(1);

    println!("\nE8a: ask cost, cold fit vs cached fit, by history size (5-dim space)\n");
    println!(
        "{:<8} {:>8} {:>12} {:>12} {:>9}",
        "sampler", "history", "cold", "cached", "speedup"
    );
    println!("{}", "-".repeat(54));
    let mut rows = Vec::new();
    for sampler_name in ["tpe", "gp", "cmaes", "random"] {
        let sampler = make_sampler(&AlgoConfig::new(sampler_name)).unwrap();
        for n in [100usize, 1_000, 10_000, 100_000] {
            if sampler_name == "gp" && n > 1_000 {
                // GP caps its conditioning set at 256; larger histories
                // only grow the (identical) pre-cap scan.
                continue;
            }
            let obs = history(&space, n, &mut rng);
            let iters = match n {
                100_000 => 3,
                10_000 => 10,
                _ => 30,
            };
            // Cold: what every ask paid before the fit cache — refit
            // from the history window, then draw.
            let mut r2 = Rng::new(9);
            let cold = bench(3, iters, || {
                let fit = sampler.fit(&space, &obs, Direction::Minimize);
                let _ =
                    sampler.suggest_fitted(&space, fit.as_ref(), n as u64, &mut r2);
            });
            // Cached: what an ask pays while no tell has landed — the
            // engine reuses the study's fit and only draws.
            let fit = sampler.fit(&space, &obs, Direction::Minimize);
            let mut r3 = Rng::new(9);
            let cached = bench(3, iters.max(30), || {
                let _ =
                    sampler.suggest_fitted(&space, fit.as_ref(), n as u64, &mut r3);
            });
            let speedup = cold.mean() / cached.mean().max(1e-12);
            println!(
                "{:<8} {:>8} {:>12} {:>12} {:>8.1}x",
                sampler_name,
                n,
                fmt_duration(cold.mean()),
                fmt_duration(cached.mean()),
                speedup
            );
            let mut row = Value::obj();
            row.set("sampler", sampler_name)
                .set("history", n as u64)
                .set("cold_fit_mean_s", cold.mean())
                .set("cached_ask_mean_s", cached.mean())
                .set("speedup", speedup);
            rows.push(Value::Obj(row));
        }
    }
    // E8a addendum: the cached TPE ask above spends its time in EI
    // scoring, which now runs through linalg's batched column kernel.
    // Measure that kernel against the scalar per-point slice directly on
    // a fitted Parzen mixture, and check the two are bit-identical (the
    // refactor's contract: same picks, same RNG stream, faster walls).
    println!("\nE8a+: mixture log-pdf, scalar loop vs batched column pass (24 points)\n");
    println!("{:<12} {:>12} {:>12} {:>9}", "components", "scalar", "batched", "speedup");
    println!("{}", "-".repeat(50));
    let mut mix_rows = Vec::new();
    for n in [32usize, 256, 1024] {
        let mut r = Rng::new(7);
        let fit_pts: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let parzen = hopaas::coordinator::samplers::tpe::Parzen::fit(&fit_pts);
        let points: Vec<f64> = (0..24).map(|_| r.f64()).collect();
        let mut batched = vec![0.0f64; points.len()];
        parzen.log_pdf_many(&points, &mut batched);
        for (x, b) in points.iter().zip(&batched) {
            assert_eq!(
                parzen.log_pdf(*x).to_bits(),
                b.to_bits(),
                "batched mixture eval diverged from scalar at n={n}"
            );
        }
        let scalar_s = bench(5, 200, || {
            let s: f64 = points.iter().map(|&x| parzen.log_pdf(x)).sum();
            assert!(s.is_finite());
        });
        let batched_s = bench(5, 200, || {
            parzen.log_pdf_many(&points, &mut batched);
            assert!(batched[0].is_finite());
        });
        let speedup = scalar_s.mean() / batched_s.mean().max(1e-12);
        println!(
            "{:<12} {:>12} {:>12} {:>8.1}x",
            n,
            fmt_duration(scalar_s.mean()),
            fmt_duration(batched_s.mean()),
            speedup
        );
        let mut row = Value::obj();
        row.set("components", n as u64)
            .set("scalar_mean_s", scalar_s.mean())
            .set("batched_mean_s", batched_s.mean())
            .set("speedup", speedup);
        mix_rows.push(Value::Obj(row));
    }

    let mut out = Value::obj();
    out.set("bench", "samplers")
        .set("space_dims", 5u64)
        .set("rows", Value::Arr(rows))
        .set("mixture_eval", Value::Arr(mix_rows));
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_samplers.json");
    std::fs::write(&json_path, Value::Obj(out).to_pretty()).unwrap();
    println!("\nwrote {}", json_path.display());

    // E8b: in-process router dispatch cost (no TCP) — isolates the HTTP
    // parse/dispatch overhead from socket costs.
    println!("\nE8b: in-process dispatch (no TCP) vs full HTTP round-trip\n");
    {
        let engine = Arc::new(hopaas::coordinator::engine::Engine::in_memory(
            Default::default(),
        ));
        let tokens = Arc::new(hopaas::coordinator::auth::TokenService::new(b"s"));
        let router = build_router(engine, tokens, false);
        let req = hopaas::http::Request {
            method: hopaas::http::Method::Get,
            path: "/api/version".into(),
            query: String::new(),
            headers: hopaas::http::Headers::new(),
            body: Vec::new(),
        };
        let s = bench(100, 5000, || {
            let resp = router.dispatch(&req);
            assert_eq!(resp.status, 200);
        });
        println!("router dispatch (version): mean {}", fmt_duration(s.mean()));
    }
    {
        let server = HopaasServer::start(
            "127.0.0.1:0",
            HopaasConfig { auth_required: false, ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let s = bench(50, 2000, || {
            assert_eq!(c.get("/api/version").unwrap().status, 200);
        });
        println!("full HTTP round-trip:      mean {}", fmt_duration(s.mean()));
        server.stop();
    }

    // E8c: ask throughput vs server worker threads.
    println!("\nE8c: ask throughput vs server worker threads (16 clients)\n");
    println!("{:<10} {:>12} {:>12}", "workers", "req/s", "p99");
    println!("{}", "-".repeat(36));
    for workers in [1usize, 2, 4, 8, 16] {
        let server = HopaasServer::start(
            "127.0.0.1:0",
            HopaasConfig {
                auth_required: false,
                http: ServerConfig { workers, ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap();
        let addr = server.addr();
        let body = parse(
            r#"{"study_name": "t", "properties": {"x": {"low": 0.0, "high": 1.0}},
             "sampler": {"name": "random"}}"#,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let body = body.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let mut s = hopaas::bench::Samples::new();
                    for _ in 0..100 {
                        s.time(|| {
                            let r = c.post_json("/api/ask/x", &body).unwrap();
                            assert_eq!(r.status, 200);
                        });
                    }
                    s
                })
            })
            .collect();
        let mut all = hopaas::bench::Samples::new();
        for h in handles {
            all.merge(&h.join().unwrap());
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<10} {:>12.0} {:>12}",
            workers,
            all.len() as f64 / wall,
            fmt_duration(all.quantile(0.99))
        );
        server.stop();
    }

    // Keep Server linked (suppress unused warnings in minimal builds).
    let _ = Server::bind("127.0.0.1:0", Default::default(), ServerConfig::default());
}

//! F1 — the Figure 1 workflow, timed end to end.
//!
//! One full client-server optimization loop per iteration: ask → k ×
//! should_prune → tell, over real HTTP, reporting the complete trial
//! round-trip cost (the service-side overhead a computing node pays per
//! trial — which must be negligible against minutes-long trainings).
//!
//! Run: `cargo bench --bench workflow`

use hopaas::bench::{fmt_duration, Samples};
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::Objective;
use hopaas::worker::{HopaasClient, StudySpec};

fn main() {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: true, ..Default::default() },
    )
    .unwrap();
    let tok = server.bootstrap_token.clone();

    println!("\nF1: full workflow round-trip (ask + k·should_prune + tell)\n");
    println!(
        "{:<22} {:>8} {:>10} {:>10} {:>10} {:>12}",
        "configuration", "k", "p50", "p95", "p99", "trials/s"
    );
    println!("{}", "-".repeat(78));

    for (sampler, pruner, k) in [
        ("random", None, 0u64),
        ("random", Some("median"), 5),
        ("tpe", None, 0),
        ("tpe", Some("median"), 5),
        ("tpe", Some("median"), 20),
        ("gp", Some("median"), 5),
    ] {
        let mut client = HopaasClient::connect(server.addr(), tok.clone()).unwrap();
        let mut spec = StudySpec::new(&format!("wf-{sampler}-{}-{k}", pruner.unwrap_or("none")))
            .properties_json(Objective::Ackley.properties())
            .sampler(sampler);
        if let Some(p) = pruner {
            spec = spec.pruner(p);
        }

        // Warm the study with enough history that TPE/GP are in model
        // mode (past n_startup).
        for _ in 0..15 {
            let t = client.ask(&spec).unwrap();
            let v = Objective::Ackley.eval_params(&t.params);
            client.tell(&t, v).unwrap();
        }

        let mut s = Samples::new();
        let t0 = std::time::Instant::now();
        let iters = 100;
        for _ in 0..iters {
            s.time(|| {
                let t = client.ask(&spec).unwrap();
                let v = Objective::Ackley.eval_params(&t.params);
                let mut pruned = false;
                for step in 1..=k {
                    if client.should_prune(&t, step, v + 1.0 / step as f64).unwrap() {
                        pruned = true;
                        break;
                    }
                }
                if !pruned {
                    client.tell(&t, v).unwrap();
                }
            });
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<22} {:>8} {:>10} {:>10} {:>10} {:>12.1}",
            format!("{sampler}+{}", pruner.unwrap_or("none")),
            k,
            fmt_duration(s.quantile(0.5)),
            fmt_duration(s.quantile(0.95)),
            fmt_duration(s.quantile(0.99)),
            iters as f64 / wall
        );
    }

    println!(
        "\nworkflow overhead per trial is O(ms) — negligible against the\n\
         minutes-long GAN trainings of §4 (see gan_step bench)."
    );
    server.stop();
}

//! Recovery benchmark: replay wall-clock of a large WAL at 1 / 4 / 8 /
//! 16 shards, plus a compaction-latency phase at 1 / 4 / 16 shards ×
//! 1 / 4 segment-cut threads.
//!
//! Recovery partitions the log by study and replays each partition on
//! its own thread (one per shard by default), so wall-clock should
//! scale *down* as the shard count grows — the 1-shard row is the
//! sequential-replay baseline. The compaction phase measures
//! `Engine::compact` wall time: segment cuts fan out on the
//! `--compact-threads` side pool, so on a multi-shard store the
//! 4-thread rows should beat the 1-thread (sequential-cut) baseline.
//! Results are printed as tables and written to `BENCH_recovery.json`
//! at the repository root so CI can archive both trajectories.
//!
//! Run: `cargo bench --bench recovery [-- --records N]`
//! (default 120_000 records ≈ 60k ask+tell pairs across 16 studies).

use hopaas::bench::{fmt_duration, Table};
use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::json::{parse, Value};
use std::sync::Arc;
use std::time::Instant;

const N_STUDIES: usize = 16;
const BUILD_THREADS: usize = 8;

fn ask_body(study: usize) -> Value {
    parse(&format!(
        r#"{{
        "study_name": "recovery-{study}",
        "properties": {{
            "x": {{"low": 0.0, "high": 1.0}},
            "y": {{"low": 1e-4, "high": 1.0, "type": "loguniform"}}
        }},
        "direction": "minimize",
        "sampler": {{"name": "random"}}
    }}"#
    ))
    .unwrap()
}

/// Scratch directory (not auto-deleted on panic; best-effort cleanup).
struct Scratch(std::path::PathBuf);

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let records: u64 = args
        .iter()
        .position(|a| a == "--records")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(120_000);
    // Each told trial costs 2 records (trial_new + trial_tell).
    let trials_total = (records / 2).max(N_STUDIES as u64);
    let per_thread = trials_total / BUILD_THREADS as u64;

    let dir = Scratch(std::env::temp_dir().join(format!(
        "hopaas-bench-recovery-{}",
        std::process::id()
    )));
    let _ = std::fs::remove_dir_all(&dir.0);
    std::fs::create_dir_all(&dir.0).unwrap();

    println!("\nrecovery: building a ~{records}-record log ({trials_total} told trials, {N_STUDIES} studies)\n");
    let t0 = Instant::now();
    {
        let engine = Arc::new(
            Engine::open(
                &dir.0,
                EngineConfig {
                    n_shards: 16,
                    // Never compact while building: the point is a big log.
                    compact_after: u64::MAX,
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        let handles: Vec<_> = (0..BUILD_THREADS)
            .map(|t| {
                let engine = engine.clone();
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        let study = (t + (i as usize % 2) * BUILD_THREADS) % N_STUDIES;
                        let r = engine.ask(&ask_body(study)).unwrap();
                        engine.tell(r.trial_id, (i % 100) as f64).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
    let build_wall = t0.elapsed().as_secs_f64();
    let log_bytes = std::fs::metadata(dir.0.join("wal.log")).map(|m| m.len()).unwrap_or(0);
    println!(
        "built in {} ({:.1} MiB)\n",
        fmt_duration(build_wall),
        log_bytes as f64 / (1024.0 * 1024.0)
    );

    let table = Table::new(
        &["shards", "replay wall", "records/s", "speedup vs 1 shard"],
        &[8, 14, 12, 20],
    );
    let mut rows: Vec<Value> = Vec::new();
    let mut baseline = 0.0f64;
    for &shards in &[1usize, 4, 8, 16] {
        // Two replays per shard count, keeping the better one (first
        // run also warms the page cache for every row after the 1-shard
        // baseline, so run one throwaway warmup first).
        if shards == 1 {
            let warm = Engine::open(&dir.0, EngineConfig { n_shards: 1, ..Default::default() })
                .unwrap();
            drop(warm);
        }
        let mut best = f64::INFINITY;
        let mut recovered = 0u64;
        for _ in 0..2 {
            let t0 = Instant::now();
            let engine =
                Engine::open(&dir.0, EngineConfig { n_shards: shards, ..Default::default() })
                    .unwrap();
            let wall = t0.elapsed().as_secs_f64();
            recovered = engine.recovery_stats().recovered_records;
            best = best.min(wall);
        }
        if shards == 1 {
            baseline = best;
        }
        let speedup = baseline / best;
        table.row(&[
            &shards.to_string(),
            &fmt_duration(best),
            &format!("{:.0}", recovered as f64 / best),
            &format!("{speedup:.2}x"),
        ]);
        let mut row = Value::obj();
        row.set("shards", shards)
            .set("replay_wall_s", best)
            .set("records_per_s", recovered as f64 / best)
            .set("speedup_vs_1_shard", speedup);
        rows.push(Value::Obj(row));
    }

    // Phase 2: compaction latency — total wall of `Engine::compact` at
    // 1/4/16 shards × 1/4 cut threads. Each cell builds its own fresh
    // store (a compacted store has nothing left to cut), smaller than
    // the replay log so the phase stays cheap in CI.
    let compact_trials = ((records / 8).max(N_STUDIES as u64)).min(10_000);
    println!("\ncompaction: {compact_trials} told trials per cell, {N_STUDIES} studies\n");
    let ctable = Table::new(
        &["shards", "threads", "compact wall", "speedup vs 1 thread"],
        &[8, 9, 14, 20],
    );
    let mut compact_rows: Vec<Value> = Vec::new();
    for &shards in &[1usize, 4, 16] {
        let mut thread1 = 0.0f64;
        for &threads in &[1usize, 4] {
            let cdir = Scratch(std::env::temp_dir().join(format!(
                "hopaas-bench-compact-{}-{shards}-{threads}",
                std::process::id()
            )));
            let _ = std::fs::remove_dir_all(&cdir.0);
            std::fs::create_dir_all(&cdir.0).unwrap();
            let engine = Engine::open(
                &cdir.0,
                EngineConfig {
                    n_shards: shards,
                    compact_threads: threads,
                    compact_after: u64::MAX,
                    ..Default::default()
                },
            )
            .unwrap();
            for i in 0..compact_trials {
                let study = (i % N_STUDIES as u64) as usize;
                let r = engine.ask(&ask_body(study)).unwrap();
                engine.tell(r.trial_id, (i % 100) as f64).unwrap();
            }
            let t0 = Instant::now();
            engine.compact().unwrap();
            let wall = t0.elapsed().as_secs_f64();
            if threads == 1 {
                thread1 = wall;
            }
            let speedup = thread1 / wall;
            ctable.row(&[
                &shards.to_string(),
                &threads.to_string(),
                &fmt_duration(wall),
                &format!("{speedup:.2}x"),
            ]);
            let mut row = Value::obj();
            row.set("shards", shards)
                .set("compact_threads", threads)
                .set("compact_wall_s", wall)
                .set("speedup_vs_1_thread", speedup);
            compact_rows.push(Value::Obj(row));
        }
    }

    let mut out = Value::obj();
    out.set("bench", "recovery")
        .set("records", records)
        .set("log_bytes", log_bytes)
        .set("build_wall_s", build_wall)
        .set("rows", Value::Arr(rows))
        .set("compaction", Value::Arr(compact_rows));
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_recovery.json");
    std::fs::write(&json_path, Value::Obj(out).to_pretty()).unwrap();
    println!("\nwrote {}", json_path.display());
}

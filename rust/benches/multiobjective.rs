//! E9 — multi-objective optimization (the paper's §5 future work,
//! implemented here): NSGA-II vs random on the ZDT suite, measured by
//! dominated hypervolume of the Pareto front (higher = better), through
//! the real engine.
//!
//! Run: `cargo bench --bench multiobjective`

use hopaas::bench::mean_std;
use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::coordinator::mo::hypervolume;
use hopaas::json::Value;
use hopaas::objectives::multi::{MoProblem, ALL_MO};

const TRIALS: usize = 200;
const SEEDS: u64 = 5;

fn ask_body(problem: MoProblem, sampler: &str, seed: u64) -> Value {
    let mut o = Value::obj();
    o.set("study_name", format!("{}-{sampler}-{seed}", problem.name()))
        .set("properties", problem.properties())
        .set(
            "direction",
            Value::Arr(vec![Value::Str("minimize".into()), Value::Str("minimize".into())]),
        )
        .set("sampler", {
            let mut s = Value::obj();
            s.set("name", sampler);
            Value::Obj(s)
        });
    Value::Obj(o)
}

fn run(problem: MoProblem, sampler: &str, seed: u64) -> (f64, usize) {
    let engine = Engine::in_memory(EngineConfig { seed: 500 + seed, ..Default::default() });
    let body = ask_body(problem, sampler, seed);
    let mut points: Vec<Vec<f64>> = Vec::new();
    let mut study_id = 0;
    for _ in 0..TRIALS {
        let reply = engine.ask(&body).unwrap();
        study_id = reply.study_id;
        let [f1, f2] = problem.eval_params(&reply.params);
        engine.tell_values(reply.trial_id, vec![f1, f2]).unwrap();
        points.push(vec![f1, f2]);
    }
    let r = problem.hv_reference();
    let hv = hypervolume(&points, &r, 0);
    let front_size = engine
        .pareto_json(study_id)
        .unwrap()
        .as_arr()
        .unwrap()
        .len();
    (hv, front_size)
}

fn main() {
    println!(
        "\nE9: multi-objective (NSGA-II vs random), {TRIALS} trials, {SEEDS} seeds, hypervolume ↑\n"
    );
    println!(
        "{:<8} {:<8} {:>16} {:>12}",
        "problem", "sampler", "hypervolume", "front size"
    );
    println!("{}", "-".repeat(48));
    for problem in ALL_MO {
        let mut results: Vec<(String, f64)> = Vec::new();
        for sampler in ["random", "nsga2"] {
            let mut hvs = Vec::new();
            let mut fronts = Vec::new();
            for seed in 0..SEEDS {
                let (hv, fs) = run(problem, sampler, seed);
                hvs.push(hv);
                fronts.push(fs as f64);
            }
            let (mhv, shv) = mean_std(&hvs);
            let (mf, _) = mean_std(&fronts);
            println!(
                "{:<8} {:<8} {:>10.3}±{:<5.3} {:>12.1}",
                problem.name(),
                sampler,
                mhv,
                shv,
                mf
            );
            results.push((sampler.to_string(), mhv));
        }
        let random = results.iter().find(|(s, _)| s == "random").unwrap().1;
        let nsga2 = results.iter().find(|(s, _)| s == "nsga2").unwrap().1;
        println!(
            "  -> nsga2 {nsga2:.3} vs random {random:.3}  {}",
            if nsga2 > random { "[OK: NSGA-II wins]" } else { "[!! random won]" }
        );
        println!();
    }
}

//! E4 — sampler comparison: does Bayesian optimization "focus on those
//! regions of the hyperparameter space where the model performs better"
//! (paper §1)?
//!
//! Every sampler × objective × 10 seeds, 100 sequential trials each
//! (through the real engine, so the suggest path is exactly what serves
//! `ask`). Reports mean best-so-far at 25/50/100 trials. Expected shape:
//! TPE/GP/CMA-ES beat random/qmc on the structured objectives at equal
//! budget; random is competitive only on the pathological ones.
//!
//! Run: `cargo bench --bench samplers`

use hopaas::bench::mean_std;
use hopaas::coordinator::engine::{Engine, EngineConfig};
use hopaas::json::Value;
use hopaas::objectives::{Objective, ALL};

const SEEDS: u64 = 10;
const TRIALS: usize = 100;
const SAMPLERS: [&str; 5] = ["random", "qmc", "tpe", "gp", "cmaes"];

fn ask_body(objective: Objective, sampler: &str, seed: u64) -> Value {
    let mut o = Value::obj();
    o.set("study_name", format!("{}-{sampler}-{seed}", objective.name()))
        .set("properties", objective.properties())
        .set("direction", "minimize")
        .set("sampler", {
            let mut s = Value::obj();
            s.set("name", sampler);
            Value::Obj(s)
        });
    Value::Obj(o)
}

fn main() {
    println!("\nE4: best-so-far by sampler (mean over {SEEDS} seeds), minimize\n");
    println!(
        "{:<16} {:<8} {:>14} {:>14} {:>14}",
        "objective", "sampler", "@25", "@50", "@100"
    );
    println!("{}", "-".repeat(70));

    for objective in ALL {
        let mut rows: Vec<(String, [f64; 3])> = Vec::new();
        for sampler in SAMPLERS {
            let mut at25 = Vec::new();
            let mut at50 = Vec::new();
            let mut at100 = Vec::new();
            for seed in 0..SEEDS {
                let engine = Engine::in_memory(EngineConfig {
                    seed: 1000 + seed,
                    ..Default::default()
                });
                let body = ask_body(objective, sampler, seed);
                let mut best = f64::INFINITY;
                for t in 0..TRIALS {
                    let reply = engine.ask(&body).unwrap();
                    let v = objective.eval_params(&reply.params);
                    engine.tell(reply.trial_id, v).unwrap();
                    best = best.min(v);
                    if t + 1 == 25 {
                        at25.push(best);
                    }
                    if t + 1 == 50 {
                        at50.push(best);
                    }
                }
                at100.push(best);
            }
            let (m25, _) = mean_std(&at25);
            let (m50, _) = mean_std(&at50);
            let (m100, s100) = mean_std(&at100);
            println!(
                "{:<16} {:<8} {:>14.4} {:>14.4} {:>8.4}±{:<6.4}",
                objective.name(),
                sampler,
                m25,
                m50,
                m100,
                s100
            );
            rows.push((sampler.to_string(), [m25, m50, m100]));
        }
        // Shape check: the best model-based sampler beats random @100.
        let random = rows.iter().find(|(s, _)| s == "random").unwrap().1[2];
        let best_model = rows
            .iter()
            .filter(|(s, _)| s == "tpe" || s == "gp" || s == "cmaes")
            .map(|(_, v)| v[2])
            .fold(f64::INFINITY, f64::min);
        println!(
            "  -> model-based best {best_model:.4} vs random {random:.4}  {}",
            if best_model <= random { "[OK: BO wins]" } else { "[!! random won]" }
        );
        println!();
    }
}

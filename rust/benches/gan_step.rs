//! E6/§Perf — the GAN hot path through PJRT: per-variant compile, train
//! step, and evaluation latency, plus the end-to-end cost of one HOPAAS
//! GAN trial (the unit of the §4 campaign).
//!
//! Requires `make artifacts`. Skips gracefully otherwise (CI without
//! artifacts still runs the other benches).
//!
//! Run: `cargo bench --bench gan_step`

use hopaas::bench::{bench, fmt_duration, wall};
use hopaas::gan::{GanHyper, GanTrainer};
use hopaas::runtime::Runtime;
use std::sync::Arc;

fn main() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("gan_step: artifacts/ not built — run `make artifacts`; skipping");
        return;
    }
    let rt = Arc::new(Runtime::open(dir).unwrap());
    println!("\nE6/Perf: GAN hot path via PJRT ({})\n", rt.platform());
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "variant", "compile", "step mean", "step p99", "eval", "steps/s"
    );
    println!("{}", "-".repeat(74));

    let variants: Vec<(u64, u64)> =
        rt.manifest.variants.iter().map(|v| (v.width, v.depth)).collect();
    for (w, d) in &variants {
        let mut t = GanTrainer::new(rt.clone(), *w, *d, 1).unwrap();
        let hp = GanHyper::default();
        let (_, compile) = wall(|| t.train(1, &hp).unwrap());
        let s = bench(3, 25, || {
            t.train(1, &hp).unwrap();
        });
        let (_, eval) = wall(|| t.evaluate().unwrap());
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12} {:>10.0}",
            format!("{w}x{d}"),
            fmt_duration(compile.as_secs_f64()),
            fmt_duration(s.mean()),
            fmt_duration(s.quantile(0.99)),
            fmt_duration(eval.as_secs_f64()),
            1.0 / s.mean()
        );
    }

    // One full trial (240 steps + 4 evals) — the unit the campaign pays.
    println!("\nfull-trial cost (240 steps + 4 evals, 64x2):");
    let mut t = GanTrainer::new(rt.clone(), 64, 2, 2).unwrap();
    let hp = GanHyper { lr_g: 2e-3, lr_d: 2e-3, beta1: 0.5, beta2: 0.9, leak: 0.1 };
    let (w1, trial_wall) = wall(|| {
        for _ in 0..4 {
            t.train(60, &hp).unwrap();
            t.evaluate_with_leak(hp.leak).unwrap();
        }
        t.evaluate_with_leak(hp.leak).unwrap()
    });
    println!(
        "  {} -> final W1 {:.4}  ({:.1} trial/min/worker)",
        fmt_duration(trial_wall.as_secs_f64()),
        w1,
        60.0 / trial_wall.as_secs_f64()
    );
    println!(
        "\nservice overhead per trial is ~1ms (see workflow bench) — {:.4}% of\n\
         the trial cost: the coordinator is never the bottleneck, matching §4.",
        100.0 * 0.001 / trial_wall.as_secs_f64()
    );
}

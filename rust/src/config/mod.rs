//! Configuration files and CLI argument parsing.
//!
//! The paper's deployment is a docker-compose stack; the knobs that
//! configuration exposes (bind address, worker count, storage path,
//! secret, auth mode) live in a JSON config file and/or CLI flags here.
//! A tiny flag parser is implemented locally (`clap` is unavailable
//! offline), with `--key value` / `--key=value` / boolean flags.

use crate::coordinator::engine::EngineConfig;
use crate::coordinator::service::HopaasConfig;
use crate::http::ServerConfig;
use crate::json::Value;
use std::time::Duration;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                out.command = it.next().unwrap();
            }
        }
        // Flags that never take a value (`--flag value` would otherwise
        // swallow a following positional).
        const BOOLEAN: [&str; 6] =
            ["no-auth", "help", "verbose", "quiet", "wal-batch-adaptive", "fleet"];
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.push((k.to_string(), v.to_string()));
                } else if BOOLEAN.contains(&stripped) {
                    out.flags.push((stripped.to_string(), "true".to_string()));
                } else {
                    // `--flag value` or trailing boolean `--flag`.
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.push((stripped.to_string(), v));
                        }
                        _ => out.flags.push((stripped.to_string(), "true".to_string())),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Server configuration assembled from (optional) JSON file + CLI
/// overrides. File keys mirror the flag names.
pub fn server_config(args: &Args) -> Result<(String, HopaasConfig), String> {
    // Defaults.
    let mut addr = "127.0.0.1:8021".to_string();
    let mut workers = 128u64;
    let mut auth = true;
    let mut secret = "hopaas-dev-secret".to_string();
    let mut data_dir: Option<String> = None;
    let mut compact_after = 50_000u64;
    let mut reap_after = 3600.0f64;
    let mut seed = 0x4f50_5441_4153u64;
    let mut n_shards = 8u64;
    let mut wal_batch_max = 256u64;
    // Adaptive unless a fixed --wal-batch / "wal_batch" is given.
    let mut wal_batch_adaptive = true;
    let mut replay_threads = 0u64;
    let mut lease_timeout = 60.0f64;
    let mut site_quota = 0u64;
    let mut study_quota = 0u64;
    let mut requeue_max = 3u64;

    // Layer 1: config file.
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("config {path}: {e}"))?;
        let v = crate::json::parse(&text).map_err(|e| format!("config {path}: {e}"))?;
        let s = |key: &str, out: &mut String| {
            if let Some(x) = v.get(key).as_str() {
                *out = x.to_string();
            }
        };
        s("addr", &mut addr);
        s("secret", &mut secret);
        if let Some(x) = v.get("workers").as_u64() {
            workers = x;
        }
        if let Value::Bool(b) = v.get("auth") {
            auth = *b;
        }
        if let Some(x) = v.get("data_dir").as_str() {
            data_dir = Some(x.to_string());
        }
        if let Some(x) = v.get("compact_after").as_u64() {
            compact_after = x;
        }
        if let Some(x) = v.get("reap_after").as_f64() {
            reap_after = x;
        }
        if let Some(x) = v.get("seed").as_u64() {
            seed = x;
        }
        if let Some(x) = v.get("shards").as_u64() {
            n_shards = x;
        }
        if let Some(x) = v.get("wal_batch").as_u64() {
            wal_batch_max = x;
            wal_batch_adaptive = false;
        }
        if let Value::Bool(b) = v.get("wal_batch_adaptive") {
            wal_batch_adaptive = *b;
        }
        if let Some(x) = v.get("replay_threads").as_u64() {
            replay_threads = x;
        }
        if let Some(x) = v.get("lease_timeout").as_f64() {
            lease_timeout = x;
        }
        if let Some(x) = v.get("site_quota").as_u64() {
            site_quota = x;
        }
        if let Some(x) = v.get("study_quota").as_u64() {
            study_quota = x;
        }
        if let Some(x) = v.get("requeue_max").as_u64() {
            requeue_max = x;
        }
    }

    // Layer 2: CLI overrides.
    if let Some(a) = args.get("addr") {
        addr = a.to_string();
    }
    workers = args.get_u64("workers", workers);
    if args.get("no-auth").is_some() {
        auth = false;
    }
    if let Some(s) = args.get("secret") {
        secret = s.to_string();
    }
    if let Some(d) = args.get("data-dir") {
        data_dir = Some(d.to_string());
    }
    compact_after = args.get_u64("compact-after", compact_after);
    reap_after = args.get_f64("reap-after", reap_after);
    seed = args.get_u64("seed", seed);
    n_shards = args.get_u64("shards", n_shards).max(1);
    if args.get("wal-batch").is_some() {
        // A fixed batch size is an override of the adaptive default…
        wal_batch_max = args.get_u64("wal-batch", wal_batch_max).max(1);
        wal_batch_adaptive = false;
    }
    if args.get("wal-batch-adaptive").is_some() {
        // …unless adaptation is re-enabled explicitly (then N is the cap).
        wal_batch_adaptive = args.get_bool("wal-batch-adaptive");
    }
    replay_threads = args.get_u64("replay-threads", replay_threads);
    lease_timeout = args.get_f64("lease-timeout", lease_timeout);
    site_quota = args.get_u64("site-quota", site_quota);
    study_quota = args.get_u64("study-quota", study_quota);
    requeue_max = args.get_u64("requeue-max", requeue_max);

    let config = HopaasConfig {
        engine: EngineConfig {
            seed,
            compact_after,
            reap_after: if reap_after > 0.0 { Some(reap_after) } else { None },
            history_snapshot: args.get_u64("history-snapshot", 2048) as usize,
            n_shards: n_shards as usize,
            wal_batch_max: wal_batch_max.max(1) as usize,
            replay_threads: replay_threads as usize,
            wal_batch_adaptive,
            lease_timeout: if lease_timeout > 0.0 { Some(lease_timeout) } else { None },
            site_quota: site_quota as u32,
            study_quota: study_quota as u32,
            requeue_max: requeue_max as u32,
        },
        http: ServerConfig {
            workers: workers as usize,
            read_timeout: Duration::from_secs(args.get_u64("read-timeout", 30)),
            backlog: 1024,
        },
        auth_required: auth,
        secret: secret.into_bytes(),
        data_dir: data_dir.map(Into::into),
    };
    Ok((addr, config))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parse_forms() {
        let a = args("serve --addr 0.0.0.0:9000 --workers=4 --no-auth pos1");
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("addr"), Some("0.0.0.0:9000"));
        assert_eq!(a.get_u64("workers", 0), 4);
        assert!(a.get_bool("no-auth"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn last_flag_wins() {
        let a = args("x --seed 1 --seed 2");
        assert_eq!(a.get_u64("seed", 0), 2);
    }

    #[test]
    fn defaults_without_file() {
        let a = args("serve");
        let (addr, cfg) = server_config(&a).unwrap();
        assert_eq!(addr, "127.0.0.1:8021");
        assert!(cfg.auth_required);
        assert_eq!(cfg.http.workers, 128);
        assert!(cfg.data_dir.is_none());
    }

    #[test]
    fn file_and_cli_layering() {
        let d = TempDir::new("config");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"addr": "1.2.3.4:1", "workers": 2, "auth": false, "reap_after": 10.0}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {} --workers 16", p.display()));
        let (addr, cfg) = server_config(&a).unwrap();
        assert_eq!(addr, "1.2.3.4:1");
        assert_eq!(cfg.http.workers, 16, "CLI overrides file");
        assert!(!cfg.auth_required);
        assert_eq!(cfg.engine.reap_after, Some(10.0));
    }

    #[test]
    fn shard_flags_layer_into_engine_config() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.n_shards, 8);
        assert_eq!(cfg.engine.wal_batch_max, 256);
        assert_eq!(cfg.engine.replay_threads, 0, "0 = one replay thread per shard");
        let a = args("serve --shards 4 --wal-batch 64 --replay-threads 2");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.n_shards, 4);
        assert_eq!(cfg.engine.wal_batch_max, 64);
        assert_eq!(cfg.engine.replay_threads, 2);
        // Degenerate values clamp to 1 rather than panicking the engine.
        let a = args("serve --shards 0 --wal-batch 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.n_shards, 1);
        assert_eq!(cfg.engine.wal_batch_max, 1);
    }

    #[test]
    fn fleet_and_adaptive_batch_flags() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.wal_batch_adaptive, "adaptive batching is the default");
        assert_eq!(cfg.engine.lease_timeout, Some(60.0));
        assert_eq!(cfg.engine.site_quota, 0);
        assert_eq!(cfg.engine.study_quota, 0);
        assert_eq!(cfg.engine.requeue_max, 3);
        // A fixed --wal-batch is an override that disables adaptation.
        let a = args("serve --wal-batch 64");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(!cfg.engine.wal_batch_adaptive);
        assert_eq!(cfg.engine.wal_batch_max, 64);
        // …unless adaptation is re-enabled (N then acts as the cap).
        let a = args("serve --wal-batch 512 --wal-batch-adaptive");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.wal_batch_adaptive);
        assert_eq!(cfg.engine.wal_batch_max, 512);
        // Fleet knobs layer through; lease-timeout 0 disables expiry.
        let a = args("serve --lease-timeout 5 --site-quota 8 --study-quota 4 --requeue-max 1");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.lease_timeout, Some(5.0));
        assert_eq!(cfg.engine.site_quota, 8);
        assert_eq!(cfg.engine.study_quota, 4);
        assert_eq!(cfg.engine.requeue_max, 1);
        let a = args("serve --lease-timeout 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.lease_timeout, None);
    }

    #[test]
    fn fleet_config_file_keys() {
        let d = TempDir::new("config-fleet");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"lease_timeout": 12.5, "site_quota": 6, "wal_batch": 32}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {}", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.lease_timeout, Some(12.5));
        assert_eq!(cfg.engine.site_quota, 6);
        assert_eq!(cfg.engine.wal_batch_max, 32);
        assert!(!cfg.engine.wal_batch_adaptive, "file wal_batch fixes the size");
    }

    #[test]
    fn bad_config_file_errors() {
        let a = args("serve --config /nope/nope.json");
        assert!(server_config(&a).is_err());
    }
}

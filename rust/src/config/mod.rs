//! Configuration files and CLI argument parsing.
//!
//! The paper's deployment is a docker-compose stack; the knobs that
//! configuration exposes (bind address, worker count, storage path,
//! secret, auth mode) live in a JSON config file and/or CLI flags here.
//! A tiny flag parser is implemented locally (`clap` is unavailable
//! offline), with `--key value` / `--key=value` / boolean flags.

use crate::coordinator::engine::EngineConfig;
use crate::coordinator::service::HopaasConfig;
use crate::fleet::QuotaPolicy;
use crate::http::ServerConfig;
use crate::json::Value;
use std::collections::HashMap;
use std::time::Duration;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                out.command = it.next().unwrap();
            }
        }
        // Flags that never take a value (`--flag value` would otherwise
        // swallow a following positional).
        const BOOLEAN: [&str; 8] = [
            "no-auth",
            "help",
            "verbose",
            "quiet",
            "wal-batch-adaptive",
            "fleet",
            "site-affinity",
            "log-json",
        ];
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.push((k.to_string(), v.to_string()));
                } else if BOOLEAN.contains(&stripped) {
                    out.flags.push((stripped.to_string(), "true".to_string()));
                } else {
                    // `--flag value` or trailing boolean `--flag`.
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.push((stripped.to_string(), v));
                        }
                        _ => out.flags.push((stripped.to_string(), "true".to_string())),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Server configuration assembled from (optional) JSON file + CLI
/// overrides. File keys mirror the flag names.
pub fn server_config(args: &Args) -> Result<(String, HopaasConfig), String> {
    // Defaults.
    let mut addr = "127.0.0.1:8021".to_string();
    let mut workers = 128u64;
    let mut auth = true;
    let mut secret = "hopaas-dev-secret".to_string();
    let mut data_dir: Option<String> = None;
    let mut compact_after = 50_000u64;
    let mut compact_threads = 0u64;
    let mut reap_after = 3600.0f64;
    let mut seed = 0x4f50_5441_4153u64;
    let mut n_shards = 8u64;
    let mut wal_batch_max = 256u64;
    // Adaptive unless a fixed --wal-batch / "wal_batch" is given.
    let mut wal_batch_adaptive = true;
    let mut replay_threads = 0u64;
    let mut lease_timeout = 60.0f64;
    let mut site_quota = 0u64;
    let mut site_quota_map: HashMap<String, u32> = HashMap::new();
    let mut study_quota = 0u64;
    let mut tenant_quota = 0u64;
    let mut tenant_quota_map: HashMap<String, u32> = HashMap::new();
    let mut tenant_ask_rate = 0u64;
    let mut tenant_ask_window = 60.0f64;
    let mut fairness_horizon = 30.0f64;
    let mut site_affinity = false;
    let mut requeue_max = 3u64;
    let mut dead_worker_keep = 1024u64;
    let mut site_idle_retention = 3600.0f64;
    let mut backlog = 1024u64;
    let mut sampler_cache = true;
    let mut events_poll_timeout = 25.0f64;
    let mut trace_capacity = 2048u64;
    let mut trace_sample = 1.0f64;
    let mut trace_slow_ms = 250u64;
    let mut log_json = false;
    let mut role = "primary".to_string();
    let mut primary_url: Option<String> = None;
    let mut repl_buffer = 65_536u64;
    let mut repl_poll_timeout = 2.0f64;

    // Layer 1: config file.
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("config {path}: {e}"))?;
        let v = crate::json::parse(&text).map_err(|e| format!("config {path}: {e}"))?;
        let s = |key: &str, out: &mut String| {
            if let Some(x) = v.get(key).as_str() {
                *out = x.to_string();
            }
        };
        s("addr", &mut addr);
        s("secret", &mut secret);
        if let Some(x) = v.get("workers").as_u64() {
            workers = x;
        }
        if let Value::Bool(b) = v.get("auth") {
            auth = *b;
        }
        if let Some(x) = v.get("data_dir").as_str() {
            data_dir = Some(x.to_string());
        }
        if let Some(x) = v.get("compact_after").as_u64() {
            compact_after = x;
        }
        if let Some(x) = v.get("compact_threads").as_u64() {
            compact_threads = x;
        }
        if let Some(x) = v.get("reap_after").as_f64() {
            reap_after = x;
        }
        if let Some(x) = v.get("seed").as_u64() {
            seed = x;
        }
        if let Some(x) = v.get("shards").as_u64() {
            n_shards = x;
        }
        if let Some(x) = v.get("wal_batch").as_u64() {
            wal_batch_max = x;
            wal_batch_adaptive = false;
        }
        if let Value::Bool(b) = v.get("wal_batch_adaptive") {
            wal_batch_adaptive = *b;
        }
        if let Some(x) = v.get("replay_threads").as_u64() {
            replay_threads = x;
        }
        if let Some(x) = v.get("lease_timeout").as_f64() {
            lease_timeout = x;
        }
        if let Some(x) = v.get("site_quota").as_u64() {
            site_quota = x;
        }
        if !v.get("site_quotas").is_null() {
            site_quota_map = QuotaPolicy::map_from_json(v.get("site_quotas"))
                .map_err(|e| format!("config {path}: site_quotas: {e}"))?;
        }
        if let Some(x) = v.get("study_quota").as_u64() {
            study_quota = x;
        }
        if let Some(x) = v.get("tenant_quota").as_u64() {
            tenant_quota = x;
        }
        if !v.get("tenant_quotas").is_null() {
            tenant_quota_map = QuotaPolicy::map_from_json(v.get("tenant_quotas"))
                .map_err(|e| format!("config {path}: tenant_quotas: {e}"))?;
        }
        if let Some(x) = v.get("tenant_ask_rate").as_u64() {
            tenant_ask_rate = x;
        }
        if let Some(x) = v.get("tenant_ask_window").as_f64() {
            tenant_ask_window = x;
        }
        if let Some(x) = v.get("fairness_horizon").as_f64() {
            fairness_horizon = x;
        }
        if let Value::Bool(b) = v.get("site_affinity") {
            site_affinity = *b;
        }
        if let Some(x) = v.get("requeue_max").as_u64() {
            requeue_max = x;
        }
        if let Some(x) = v.get("dead_worker_keep").as_u64() {
            dead_worker_keep = x;
        }
        if let Some(x) = v.get("site_idle_retention").as_f64() {
            site_idle_retention = x;
        }
        if let Some(x) = v.get("backlog").as_u64() {
            backlog = x;
        }
        if let Value::Bool(b) = v.get("sampler_cache") {
            sampler_cache = *b;
        }
        if let Some(x) = v.get("events_poll_timeout").as_f64() {
            events_poll_timeout = x;
        }
        if let Some(x) = v.get("trace_capacity").as_u64() {
            trace_capacity = x;
        }
        if let Some(x) = v.get("trace_sample").as_f64() {
            trace_sample = x;
        }
        if let Some(x) = v.get("trace_slow_ms").as_u64() {
            trace_slow_ms = x;
        }
        if let Value::Bool(b) = v.get("log_json") {
            log_json = *b;
        }
        s("role", &mut role);
        if let Some(x) = v.get("primary_url").as_str() {
            primary_url = Some(x.to_string());
        }
        if let Some(x) = v.get("repl_buffer").as_u64() {
            repl_buffer = x;
        }
        if let Some(x) = v.get("repl_poll_timeout").as_f64() {
            repl_poll_timeout = x;
        }
        // File keys mirror the flag names: accept the http_-prefixed
        // spellings too ("workers"/"backlog" stay as legacy keys).
        if let Some(x) = v.get("http_workers").as_u64() {
            workers = x;
        }
        if let Some(x) = v.get("http_backlog").as_u64() {
            backlog = x;
        }
    }

    // Layer 2: CLI overrides.
    if let Some(a) = args.get("addr") {
        addr = a.to_string();
    }
    workers = args.get_u64("workers", workers);
    if args.get("no-auth").is_some() {
        auth = false;
    }
    if let Some(s) = args.get("secret") {
        secret = s.to_string();
    }
    if let Some(d) = args.get("data-dir") {
        data_dir = Some(d.to_string());
    }
    compact_after = args.get_u64("compact-after", compact_after);
    compact_threads = args.get_u64("compact-threads", compact_threads);
    reap_after = args.get_f64("reap-after", reap_after);
    seed = args.get_u64("seed", seed);
    n_shards = args.get_u64("shards", n_shards).max(1);
    if args.get("wal-batch").is_some() {
        // A fixed batch size is an override of the adaptive default…
        wal_batch_max = args.get_u64("wal-batch", wal_batch_max).max(1);
        wal_batch_adaptive = false;
    }
    if args.get("wal-batch-adaptive").is_some() {
        // …unless adaptation is re-enabled explicitly (then N is the cap).
        wal_batch_adaptive = args.get_bool("wal-batch-adaptive");
    }
    replay_threads = args.get_u64("replay-threads", replay_threads);
    lease_timeout = args.get_f64("lease-timeout", lease_timeout);
    site_quota = args.get_u64("site-quota", site_quota);
    if let Some(spec) = args.get("site-quota-map") {
        site_quota_map =
            QuotaPolicy::parse_map(spec).map_err(|e| format!("--site-quota-map: {e}"))?;
    }
    study_quota = args.get_u64("study-quota", study_quota);
    tenant_quota = args.get_u64("tenant-quota", tenant_quota);
    if let Some(spec) = args.get("tenant-quota-map") {
        tenant_quota_map =
            QuotaPolicy::parse_map(spec).map_err(|e| format!("--tenant-quota-map: {e}"))?;
    }
    tenant_ask_rate = args.get_u64("tenant-ask-rate", tenant_ask_rate);
    tenant_ask_window = args.get_f64("tenant-ask-window", tenant_ask_window);
    fairness_horizon = args.get_f64("fairness-horizon", fairness_horizon);
    if args.get("site-affinity").is_some() {
        site_affinity = args.get_bool("site-affinity");
    }
    requeue_max = args.get_u64("requeue-max", requeue_max);
    dead_worker_keep = args.get_u64("dead-worker-keep", dead_worker_keep);
    site_idle_retention = args.get_f64("site-idle-retention", site_idle_retention);
    // `--http-workers` is the explicit name for the connection-pool
    // size; `--workers` stays as the historical alias.
    workers = args.get_u64("http-workers", workers);
    backlog = args.get_u64("http-backlog", backlog);
    // Escape hatch for the sampler fit cache: `off` refits on every ask
    // (the pre-cache behavior). Suggestions are byte-identical either
    // way; the knob only exists to rule the cache out when debugging.
    if let Some(x) = args.get("sampler-cache") {
        sampler_cache = match x {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => return Err(format!("--sampler-cache: expected on|off, got '{other}'")),
        };
    }
    // Long-poll window for the events feed; 0 would make every poll an
    // immediate probe, so clamp to something that still parks readers.
    events_poll_timeout = args.get_f64("events-poll-timeout", events_poll_timeout).max(0.001);
    // Request tracing: ring capacity (0 disables the subsystem), head
    // sampling, slow-op threshold, and structured per-request logging.
    trace_capacity = args.get_u64("trace-capacity", trace_capacity);
    trace_sample = args.get_f64("trace-sample", trace_sample).clamp(0.0, 1.0);
    trace_slow_ms = args.get_u64("trace-slow-ms", trace_slow_ms);
    if args.get("log-json").is_some() {
        log_json = args.get_bool("log-json");
    }
    // Replication role: a follower replays the primary's WAL stream and
    // serves reads only (until promoted via POST /api/repl/promote).
    if let Some(r) = args.get("role") {
        role = r.to_string();
    }
    if !matches!(role.as_str(), "primary" | "follower") {
        return Err(format!("--role: expected primary|follower, got '{role}'"));
    }
    if let Some(u) = args.get("primary-url") {
        primary_url = Some(u.to_string());
    }
    repl_buffer = args.get_u64("repl-buffer", repl_buffer);
    repl_poll_timeout = args.get_f64("repl-poll-timeout", repl_poll_timeout);

    let config = HopaasConfig {
        engine: EngineConfig {
            seed,
            compact_after,
            compact_threads: compact_threads as usize,
            reap_after: if reap_after > 0.0 { Some(reap_after) } else { None },
            history_snapshot: args.get_u64("history-snapshot", 2048) as usize,
            n_shards: n_shards as usize,
            wal_batch_max: wal_batch_max.max(1) as usize,
            replay_threads: replay_threads as usize,
            wal_batch_adaptive,
            lease_timeout: if lease_timeout > 0.0 { Some(lease_timeout) } else { None },
            site_quota: site_quota as u32,
            site_quota_map,
            study_quota: study_quota as u32,
            tenant_quota: tenant_quota as u32,
            tenant_quota_map,
            tenant_ask_rate: tenant_ask_rate as u32,
            tenant_ask_window: tenant_ask_window.max(1.0),
            fairness_horizon: fairness_horizon.max(1.0),
            site_affinity,
            requeue_max: requeue_max as u32,
            dead_worker_keep: dead_worker_keep as usize,
            site_idle_retention: site_idle_retention.max(1.0),
            sampler_cache,
            trace_capacity: trace_capacity as usize,
            trace_sample,
            trace_slow_ms,
            log_json,
            follower: role == "follower",
            primary_url: primary_url.clone(),
            repl_buffer: repl_buffer.max(1) as usize,
        },
        http: ServerConfig {
            workers: workers as usize,
            read_timeout: Duration::from_secs(args.get_u64("read-timeout", 30)),
            backlog: backlog.max(1) as usize,
        },
        auth_required: auth,
        secret: secret.into_bytes(),
        data_dir: data_dir.map(Into::into),
        events_poll_timeout: Duration::from_secs_f64(events_poll_timeout),
        repl_poll_timeout: Duration::from_secs_f64(repl_poll_timeout.max(0.001)),
    };
    Ok((addr, config))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parse_forms() {
        let a = args("serve --addr 0.0.0.0:9000 --workers=4 --no-auth pos1");
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("addr"), Some("0.0.0.0:9000"));
        assert_eq!(a.get_u64("workers", 0), 4);
        assert!(a.get_bool("no-auth"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn last_flag_wins() {
        let a = args("x --seed 1 --seed 2");
        assert_eq!(a.get_u64("seed", 0), 2);
    }

    #[test]
    fn defaults_without_file() {
        let a = args("serve");
        let (addr, cfg) = server_config(&a).unwrap();
        assert_eq!(addr, "127.0.0.1:8021");
        assert!(cfg.auth_required);
        assert_eq!(cfg.http.workers, 128);
        assert!(cfg.data_dir.is_none());
    }

    #[test]
    fn file_and_cli_layering() {
        let d = TempDir::new("config");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"addr": "1.2.3.4:1", "workers": 2, "auth": false, "reap_after": 10.0}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {} --workers 16", p.display()));
        let (addr, cfg) = server_config(&a).unwrap();
        assert_eq!(addr, "1.2.3.4:1");
        assert_eq!(cfg.http.workers, 16, "CLI overrides file");
        assert!(!cfg.auth_required);
        assert_eq!(cfg.engine.reap_after, Some(10.0));
    }

    #[test]
    fn shard_flags_layer_into_engine_config() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.n_shards, 8);
        assert_eq!(cfg.engine.wal_batch_max, 256);
        assert_eq!(cfg.engine.replay_threads, 0, "0 = one replay thread per shard");
        let a = args("serve --shards 4 --wal-batch 64 --replay-threads 2");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.n_shards, 4);
        assert_eq!(cfg.engine.wal_batch_max, 64);
        assert_eq!(cfg.engine.replay_threads, 2);
        // Degenerate values clamp to 1 rather than panicking the engine.
        let a = args("serve --shards 0 --wal-batch 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.n_shards, 1);
        assert_eq!(cfg.engine.wal_batch_max, 1);
    }

    #[test]
    fn fleet_and_adaptive_batch_flags() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.wal_batch_adaptive, "adaptive batching is the default");
        assert_eq!(cfg.engine.lease_timeout, Some(60.0));
        assert_eq!(cfg.engine.site_quota, 0);
        assert_eq!(cfg.engine.study_quota, 0);
        assert_eq!(cfg.engine.requeue_max, 3);
        // A fixed --wal-batch is an override that disables adaptation.
        let a = args("serve --wal-batch 64");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(!cfg.engine.wal_batch_adaptive);
        assert_eq!(cfg.engine.wal_batch_max, 64);
        // …unless adaptation is re-enabled (N then acts as the cap).
        let a = args("serve --wal-batch 512 --wal-batch-adaptive");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.wal_batch_adaptive);
        assert_eq!(cfg.engine.wal_batch_max, 512);
        // Fleet knobs layer through; lease-timeout 0 disables expiry.
        let a = args("serve --lease-timeout 5 --site-quota 8 --study-quota 4 --requeue-max 1");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.lease_timeout, Some(5.0));
        assert_eq!(cfg.engine.site_quota, 8);
        assert_eq!(cfg.engine.study_quota, 4);
        assert_eq!(cfg.engine.requeue_max, 1);
        let a = args("serve --lease-timeout 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.lease_timeout, None);
    }

    #[test]
    fn fleet_config_file_keys() {
        let d = TempDir::new("config-fleet");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"lease_timeout": 12.5, "site_quota": 6, "wal_batch": 32}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {}", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.lease_timeout, Some(12.5));
        assert_eq!(cfg.engine.site_quota, 6);
        assert_eq!(cfg.engine.wal_batch_max, 32);
        assert!(!cfg.engine.wal_batch_adaptive, "file wal_batch fixes the size");
    }

    #[test]
    fn trace_flags_layer_into_engine_config() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.trace_capacity, 2048);
        assert_eq!(cfg.engine.trace_sample, 1.0);
        assert_eq!(cfg.engine.trace_slow_ms, 250);
        assert!(!cfg.engine.log_json);
        // `--log-json` is boolean: a following positional must survive.
        let a = args("serve --trace-capacity 64 --trace-sample 0.25 --trace-slow-ms 10 --log-json pos");
        assert_eq!(a.positional(), &["pos".to_string()]);
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.trace_capacity, 64);
        assert_eq!(cfg.engine.trace_sample, 0.25);
        assert_eq!(cfg.engine.trace_slow_ms, 10);
        assert!(cfg.engine.log_json);
        // Out-of-range sampling clamps; capacity 0 disables tracing.
        let a = args("serve --trace-sample 7 --trace-capacity 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.trace_sample, 1.0);
        assert_eq!(cfg.engine.trace_capacity, 0);
        // File keys mirror the flag names; CLI still overrides.
        let d = TempDir::new("config-trace");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"trace_capacity": 16, "trace_sample": 0.5, "trace_slow_ms": 99, "log_json": true}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {} --trace-slow-ms 7", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.trace_capacity, 16);
        assert_eq!(cfg.engine.trace_sample, 0.5);
        assert_eq!(cfg.engine.trace_slow_ms, 7, "CLI overrides file");
        assert!(cfg.engine.log_json);
    }

    #[test]
    fn bad_config_file_errors() {
        let a = args("serve --config /nope/nope.json");
        assert!(server_config(&a).is_err());
    }

    #[test]
    fn compaction_and_ask_rate_flags_layer_into_engine_config() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.compact_threads, 0, "0 = min(shards, cores)");
        assert_eq!(cfg.engine.tenant_ask_rate, 0, "rate limiting off by default");
        assert_eq!(cfg.engine.tenant_ask_window, 60.0);
        let a = args("serve --compact-threads 4 --tenant-ask-rate 30 --tenant-ask-window 10");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.compact_threads, 4);
        assert_eq!(cfg.engine.tenant_ask_rate, 30);
        assert_eq!(cfg.engine.tenant_ask_window, 10.0);
        // A degenerate window clamps to a second instead of dividing by
        // (almost) zero in the ledger.
        let a = args("serve --tenant-ask-window 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.tenant_ask_window, 1.0);
        // File keys mirror the flags.
        let d = TempDir::new("config-compact");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"compact_threads": 2, "tenant_ask_rate": 5, "tenant_ask_window": 30.0}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {}", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.compact_threads, 2);
        assert_eq!(cfg.engine.tenant_ask_rate, 5);
        assert_eq!(cfg.engine.tenant_ask_window, 30.0);
    }

    #[test]
    fn quota_policy_flags_layer_into_engine_config() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.site_quota_map.is_empty());
        assert_eq!(cfg.engine.tenant_quota, 0);
        assert_eq!(cfg.engine.fairness_horizon, 30.0);
        assert!(!cfg.engine.site_affinity);
        assert_eq!(cfg.engine.dead_worker_keep, 1024);
        assert_eq!(cfg.engine.site_idle_retention, 3600.0);
        let a = args(
            "serve --site-quota 2 --site-quota-map marconi100=64,private=1 \
             --tenant-quota 4 --tenant-quota-map alice=8 --fairness-horizon 5 \
             --site-affinity --dead-worker-keep 64 --site-idle-retention 120",
        );
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.site_quota, 2);
        assert_eq!(cfg.engine.site_quota_map.get("marconi100"), Some(&64));
        assert_eq!(cfg.engine.site_quota_map.get("private"), Some(&1));
        assert_eq!(cfg.engine.tenant_quota, 4);
        assert_eq!(cfg.engine.tenant_quota_map.get("alice"), Some(&8));
        assert_eq!(cfg.engine.fairness_horizon, 5.0);
        assert!(cfg.engine.site_affinity);
        assert_eq!(cfg.engine.dead_worker_keep, 64);
        assert_eq!(cfg.engine.site_idle_retention, 120.0);
        // Malformed maps are a config error, not a silent policy hole.
        let a = args("serve --site-quota-map marconi100");
        assert!(server_config(&a).is_err());
        let a = args("serve --tenant-quota-map alice=lots");
        assert!(server_config(&a).is_err());
    }

    #[test]
    fn quota_policy_config_file_keys() {
        let d = TempDir::new("config-policy");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"site_quota": 2, "site_quotas": {"hpc": 64}, "tenant_quota": 3,
                "tenant_quotas": {"alice": 9}, "fairness_horizon": 12.5,
                "site_affinity": true, "dead_worker_keep": 10,
                "site_idle_retention": 60.0, "backlog": 16}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {}", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.site_quota, 2);
        assert_eq!(cfg.engine.site_quota_map.get("hpc"), Some(&64));
        assert_eq!(cfg.engine.tenant_quota, 3);
        assert_eq!(cfg.engine.tenant_quota_map.get("alice"), Some(&9));
        assert_eq!(cfg.engine.fairness_horizon, 12.5);
        assert!(cfg.engine.site_affinity);
        assert_eq!(cfg.engine.dead_worker_keep, 10);
        assert_eq!(cfg.engine.site_idle_retention, 60.0);
        assert_eq!(cfg.http.backlog, 16);
        // CLI overrides the file, map flags replace file maps wholesale.
        let a = args(&format!(
            "serve --config {} --tenant-quota 5 --site-quota-map hpc=1",
            p.display()
        ));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.engine.tenant_quota, 5);
        assert_eq!(cfg.engine.site_quota_map.get("hpc"), Some(&1));
        // A malformed file map is a config error, mirroring the flags.
        let bad = d.path().join("bad.json");
        std::fs::write(&bad, r#"{"site_quotas": {"hpc": "lots"}}"#).unwrap();
        let a = args(&format!("serve --config {}", bad.display()));
        assert!(server_config(&a).is_err());
        // The http_-prefixed file keys mirror the flags; legacy
        // workers/backlog keys still work (tested above).
        let http = d.path().join("http.json");
        std::fs::write(&http, r#"{"http_workers": 6, "http_backlog": 12}"#).unwrap();
        let a = args(&format!("serve --config {}", http.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.http.workers, 6);
        assert_eq!(cfg.http.backlog, 12);
    }

    #[test]
    fn sampler_cache_flag_and_file_key() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.sampler_cache, "fit cache is on by default");
        let a = args("serve --sampler-cache off");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(!cfg.engine.sampler_cache);
        let a = args("serve --sampler-cache on");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.sampler_cache);
        // Anything other than on/off is a config error, not a silent on.
        let a = args("serve --sampler-cache maybe");
        assert!(server_config(&a).is_err());
        // The file key mirrors the flag; the flag overrides the file.
        let d = TempDir::new("config-sampler-cache");
        let p = d.path().join("hopaas.json");
        std::fs::write(&p, r#"{"sampler_cache": false}"#).unwrap();
        let a = args(&format!("serve --config {}", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert!(!cfg.engine.sampler_cache);
        let a = args(&format!("serve --config {} --sampler-cache on", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.sampler_cache);
    }

    #[test]
    fn events_poll_timeout_flag_and_file_key() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.events_poll_timeout, Duration::from_secs(25));
        let a = args("serve --events-poll-timeout 2.5");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.events_poll_timeout, Duration::from_secs_f64(2.5));
        let d = TempDir::new("config-events");
        let p = d.path().join("hopaas.json");
        std::fs::write(&p, r#"{"events_poll_timeout": 1.5}"#).unwrap();
        let a = args(&format!("serve --config {}", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.events_poll_timeout, Duration::from_secs_f64(1.5));
        // Zero clamps to a sane floor instead of turning every poll
        // into an immediate probe.
        let a = args("serve --events-poll-timeout 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.events_poll_timeout > Duration::ZERO);
    }

    #[test]
    fn replication_flags_and_file_keys() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert!(!cfg.engine.follower, "primary is the default role");
        assert!(cfg.engine.primary_url.is_none());
        assert_eq!(cfg.engine.repl_buffer, 65_536);
        assert_eq!(cfg.repl_poll_timeout, Duration::from_secs_f64(2.0));
        let a = args(
            "serve --role follower --primary-url http://10.0.0.1:8021 \
             --repl-buffer 128 --repl-poll-timeout 0.5",
        );
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.follower);
        assert_eq!(cfg.engine.primary_url.as_deref(), Some("http://10.0.0.1:8021"));
        assert_eq!(cfg.engine.repl_buffer, 128);
        assert_eq!(cfg.repl_poll_timeout, Duration::from_secs_f64(0.5));
        // Unknown roles are a config error, not a silent primary.
        let a = args("serve --role observer");
        assert!(server_config(&a).is_err());
        // File keys mirror the flags; CLI overrides.
        let d = TempDir::new("config-repl");
        let p = d.path().join("hopaas.json");
        std::fs::write(
            &p,
            r#"{"role": "follower", "primary_url": "10.0.0.2:8021",
                "repl_buffer": 256, "repl_poll_timeout": 1.0}"#,
        )
        .unwrap();
        let a = args(&format!("serve --config {}", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert!(cfg.engine.follower);
        assert_eq!(cfg.engine.primary_url.as_deref(), Some("10.0.0.2:8021"));
        assert_eq!(cfg.engine.repl_buffer, 256);
        let a = args(&format!("serve --config {} --role primary", p.display()));
        let (_, cfg) = server_config(&a).unwrap();
        assert!(!cfg.engine.follower, "CLI role overrides file");
    }

    #[test]
    fn http_pool_flags() {
        let a = args("serve");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.http.workers, 128);
        assert_eq!(cfg.http.backlog, 1024);
        // --http-workers is the explicit spelling; --workers still works
        // and --http-workers wins when both are given.
        let a = args("serve --http-workers 4 --http-backlog 8");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.http.workers, 4);
        assert_eq!(cfg.http.backlog, 8);
        let a = args("serve --workers 16 --http-workers 2");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.http.workers, 2);
        // Degenerate backlog clamps to 1 (a 0-capacity rendezvous queue
        // would shed every connection that arrives while all workers
        // are mid-request).
        let a = args("serve --http-backlog 0");
        let (_, cfg) = server_config(&a).unwrap();
        assert_eq!(cfg.http.backlog, 1);
    }
}

//! HTTP request/response types and an incremental request parser.

use std::collections::HashMap;
use std::io::{self, Read};

/// Request method. Only the verbs the HOPAAS API surface uses are
/// first-class; anything else is preserved as `Other` so the router can
/// 405 it deliberately.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Method {
    Get,
    Post,
    Head,
    Put,
    Delete,
    Options,
    Other(String),
}

impl Method {
    pub fn from_str(s: &str) -> Method {
        match s {
            "GET" => Method::Get,
            "POST" => Method::Post,
            "HEAD" => Method::Head,
            "PUT" => Method::Put,
            "DELETE" => Method::Delete,
            "OPTIONS" => Method::Options,
            other => Method::Other(other.to_string()),
        }
    }

    pub fn as_str(&self) -> &str {
        match self {
            Method::Get => "GET",
            Method::Post => "POST",
            Method::Head => "HEAD",
            Method::Put => "PUT",
            Method::Delete => "DELETE",
            Method::Options => "OPTIONS",
            Method::Other(s) => s,
        }
    }
}

/// Case-insensitive header multimap (stores the last value per name,
/// which is what the service semantics need).
#[derive(Clone, Debug, Default)]
pub struct Headers {
    map: HashMap<String, String>,
}

impl Headers {
    pub fn new() -> Self {
        Headers::default()
    }

    pub fn set(&mut self, name: &str, value: impl Into<String>) {
        self.map.insert(name.to_ascii_lowercase(), value.into());
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.map.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: Method,
    /// Path component only (no query string).
    pub path: String,
    /// Raw query string (without '?'), empty if none.
    pub query: String,
    pub headers: Headers,
    pub body: Vec<u8>,
}

impl Request {
    /// Parse the query string into key/value pairs (percent-decoding the
    /// limited set the dashboard APIs use).
    pub fn query_params(&self) -> Vec<(String, String)> {
        self.query
            .split('&')
            .filter(|s| !s.is_empty())
            .map(|kv| {
                let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
                (percent_decode(k), percent_decode(v))
            })
            .collect()
    }

    /// First query parameter with the given key.
    pub fn query_param(&self, key: &str) -> Option<String> {
        self.query_params().into_iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Body interpreted as UTF-8.
    pub fn body_str(&self) -> Option<&str> {
        std::str::from_utf8(&self.body).ok()
    }
}

fn percent_decode(s: &str) -> String {
    fn hex(c: u8) -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    }
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'%' if i + 2 < b.len() => {
                if let (Some(h), Some(l)) = (hex(b[i + 1]), hex(b[i + 2])) {
                    out.push(h * 16 + l);
                    i += 3;
                } else {
                    out.push(b'%');
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// A long-poll deferral attached to a [`Response`]: the worker thread
/// does not write anything — it hands the connection to the server's
/// parked-reader pump, which re-polls until the closure yields a
/// response or the deadline passes. This is what lets thousands of idle
/// `GET /events` readers wait without pinning the fixed worker pool.
pub struct Deferred {
    /// Absolute give-up time; at the deadline `poll(true)` is called and
    /// must produce the timeout response.
    pub deadline: std::time::Instant,
    /// `poll(false)` checks for readiness (None = keep waiting);
    /// `poll(true)` is the deadline call and must return `Some`.
    pub poll: Box<dyn FnMut(bool) -> Option<Response> + Send>,
}

impl std::fmt::Debug for Deferred {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Deferred").field("deadline", &self.deadline).finish()
    }
}

/// An HTTP response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Headers,
    pub body: Vec<u8>,
    /// When set, the response is not ready: park the connection on the
    /// deferred poll instead of writing `status`/`body`.
    pub deferred: Option<Deferred>,
}

impl Clone for Response {
    /// Deferred polls are single-owner (they move to the pump); a clone
    /// is always an immediate response.
    fn clone(&self) -> Self {
        Response {
            status: self.status,
            headers: self.headers.clone(),
            body: self.body.clone(),
            deferred: None,
        }
    }
}

impl Response {
    pub fn new(status: u16) -> Self {
        Response { status, headers: Headers::new(), body: Vec::new(), deferred: None }
    }

    /// 200 with a pre-rendered JSON body (materialized-view pages —
    /// no `Value` tree is ever built).
    pub fn json_raw(body: String) -> Self {
        let mut r = Response::new(200);
        r.headers.set("content-type", "application/json");
        r.body = body.into_bytes();
        r
    }

    /// A deferred (long-poll) response; see [`Deferred`].
    pub fn deferred(
        deadline: std::time::Instant,
        poll: impl FnMut(bool) -> Option<Response> + Send + 'static,
    ) -> Self {
        let mut r = Response::new(200);
        r.deferred = Some(Deferred { deadline, poll: Box::new(poll) });
        r
    }

    /// 200 with a JSON body.
    pub fn json(value: &crate::json::Value) -> Self {
        Self::json_status(200, value)
    }

    /// Arbitrary status with a JSON body.
    pub fn json_status(status: u16, value: &crate::json::Value) -> Self {
        let mut r = Response::new(status);
        r.headers.set("content-type", "application/json");
        r.body = value.to_string().into_bytes();
        r
    }

    /// JSON error envelope `{"detail": msg}` (FastAPI's error shape,
    /// which the paper's clients would see).
    pub fn error(status: u16, msg: &str) -> Self {
        let mut o = crate::json::Value::obj();
        o.set("detail", msg);
        Self::json_status(status, &crate::json::Value::Obj(o))
    }

    /// 200 text/html.
    pub fn html(body: &str) -> Self {
        let mut r = Response::new(200);
        r.headers.set("content-type", "text/html; charset=utf-8");
        r.body = body.as_bytes().to_vec();
        r
    }

    /// 200 text/plain.
    pub fn text(body: &str) -> Self {
        let mut r = Response::new(200);
        r.headers.set("content-type", "text/plain; charset=utf-8");
        r.body = body.as_bytes().to_vec();
        r
    }

    /// Serialize head+body for the wire. `head_only` elides the body
    /// (HEAD requests) while keeping Content-Length.
    pub fn encode(&self, keep_alive: bool, head_only: bool) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.body.len() + 128);
        out.extend_from_slice(
            format!("HTTP/1.1 {} {}\r\n", self.status, super::reason(self.status)).as_bytes(),
        );
        for (k, v) in self.headers.iter() {
            out.extend_from_slice(format!("{k}: {v}\r\n").as_bytes());
        }
        out.extend_from_slice(format!("content-length: {}\r\n", self.body.len()).as_bytes());
        out.extend_from_slice(if keep_alive {
            b"connection: keep-alive\r\n"
        } else {
            b"connection: close\r\n"
        });
        out.extend_from_slice(b"\r\n");
        if !head_only {
            out.extend_from_slice(&self.body);
        }
        out
    }
}

/// Limits applied while reading a request.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// Result of a parse attempt over a buffered prefix.
pub enum ParseState {
    /// Need more bytes.
    Partial,
    /// Parsed a full request consuming `used` bytes of the buffer.
    Done { request: Request, used: usize },
    /// Protocol error — the connection should be answered with `status`
    /// and closed.
    Bad { status: u16, msg: &'static str },
}

/// Try to parse one request from `buf`.
pub fn parse_request(buf: &[u8]) -> ParseState {
    // Find end of head.
    let head_end = match find_subslice(buf, b"\r\n\r\n") {
        Some(i) => i,
        None => {
            if buf.len() > MAX_HEAD_BYTES {
                return ParseState::Bad { status: 431, msg: "header block too large" };
            }
            return ParseState::Partial;
        }
    };
    if head_end > MAX_HEAD_BYTES {
        return ParseState::Bad { status: 431, msg: "header block too large" };
    }
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return ParseState::Bad { status: 400, msg: "non-utf8 header block" },
    };
    let mut lines = head.split("\r\n");
    let request_line = match lines.next() {
        Some(l) if !l.is_empty() => l,
        _ => return ParseState::Bad { status: 400, msg: "empty request line" },
    };
    let mut parts = request_line.split(' ');
    let (m, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => return ParseState::Bad { status: 400, msg: "malformed request line" },
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return ParseState::Bad { status: 400, msg: "unsupported http version" };
    }
    let method = Method::from_str(m);
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    if !path.starts_with('/') {
        return ParseState::Bad { status: 400, msg: "target must be origin-form" };
    }

    let mut headers = Headers::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = match line.split_once(':') {
            Some((n, v)) => (n.trim(), v.trim()),
            None => return ParseState::Bad { status: 400, msg: "malformed header" },
        };
        if name.is_empty() {
            return ParseState::Bad { status: 400, msg: "empty header name" };
        }
        headers.set(name, value);
    }

    // Transfer-Encoding is not supported (the protocol never streams).
    if headers.get("transfer-encoding").is_some() {
        return ParseState::Bad { status: 400, msg: "transfer-encoding unsupported" };
    }

    let content_len = match headers.get("content-length") {
        None => 0usize,
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => return ParseState::Bad { status: 400, msg: "bad content-length" },
        },
    };
    if content_len > MAX_BODY_BYTES {
        return ParseState::Bad { status: 413, msg: "body too large" };
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_len {
        return ParseState::Partial;
    }
    let body = buf[body_start..body_start + content_len].to_vec();
    ParseState::Done {
        request: Request { method, path, query, headers, body },
        used: body_start + content_len,
    }
}

/// Blocking read of exactly one request from a stream (client-side and
/// test use; the server uses the incremental path).
pub fn read_request(stream: &mut impl Read) -> io::Result<Option<Request>> {
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    loop {
        match parse_request(&buf) {
            ParseState::Done { request, .. } => return Ok(Some(request)),
            ParseState::Bad { msg, .. } => {
                return Err(io::Error::new(io::ErrorKind::InvalidData, msg))
            }
            ParseState::Partial => {}
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "truncated request"));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

pub(crate) fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ok(raw: &str) -> Request {
        match parse_request(raw.as_bytes()) {
            ParseState::Done { request, used } => {
                assert_eq!(used, raw.len());
                request
            }
            _ => panic!("expected full parse"),
        }
    }

    #[test]
    fn parses_get() {
        let r = parse_ok("GET /api/version HTTP/1.1\r\nhost: x\r\n\r\n");
        assert_eq!(r.method, Method::Get);
        assert_eq!(r.path, "/api/version");
        assert_eq!(r.query, "");
        assert_eq!(r.headers.get("Host"), Some("x"));
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_with_body() {
        let body = r#"{"a":1}"#;
        let raw = format!(
            "POST /api/ask/tok HTTP/1.1\r\ncontent-length: {}\r\ncontent-type: application/json\r\n\r\n{}",
            body.len(),
            body
        );
        let r = parse_ok(&raw);
        assert_eq!(r.method, Method::Post);
        assert_eq!(r.body_str(), Some(body));
    }

    #[test]
    fn query_string_split_and_decoded() {
        let r = parse_ok("GET /api/studies?limit=10&name=a%20b+c HTTP/1.1\r\n\r\n");
        assert_eq!(r.path, "/api/studies");
        assert_eq!(r.query_param("limit").as_deref(), Some("10"));
        assert_eq!(r.query_param("name").as_deref(), Some("a b c"));
        assert_eq!(r.query_param("missing"), None);
    }

    #[test]
    fn partial_until_body_complete() {
        let raw = "POST /x HTTP/1.1\r\ncontent-length: 5\r\n\r\nab";
        assert!(matches!(parse_request(raw.as_bytes()), ParseState::Partial));
        let raw2 = format!("{raw}cde");
        assert!(matches!(parse_request(raw2.as_bytes()), ParseState::Done { .. }));
    }

    #[test]
    fn pipelined_requests_report_used() {
        let one = "GET /a HTTP/1.1\r\n\r\n";
        let two = format!("{one}GET /b HTTP/1.1\r\n\r\n");
        match parse_request(two.as_bytes()) {
            ParseState::Done { request, used } => {
                assert_eq!(request.path, "/a");
                assert_eq!(used, one.len());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        for raw in [
            "BROKEN\r\n\r\n",
            "GET /x HTTP/2\r\n\r\n",
            "GET x HTTP/1.1\r\n\r\n",
            "GET /x HTTP/1.1\r\nbad header\r\n\r\n",
            "POST /x HTTP/1.1\r\ncontent-length: nope\r\n\r\n",
            "POST /x HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
        ] {
            assert!(
                matches!(parse_request(raw.as_bytes()), ParseState::Bad { .. }),
                "should reject {raw:?}"
            );
        }
    }

    #[test]
    fn oversized_body_rejected() {
        let raw = format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(matches!(parse_request(raw.as_bytes()), ParseState::Bad { status: 413, .. }));
    }

    #[test]
    fn response_encode_roundtrip_fields() {
        let mut v = crate::json::Value::obj();
        v.set("ok", true);
        let resp = Response::json(&crate::json::Value::Obj(v));
        let bytes = resp.encode(true, false);
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.to_lowercase().contains("content-length: 11"));
        assert!(text.contains("keep-alive"));
        assert!(text.ends_with(r#"{"ok":true}"#));
    }

    #[test]
    fn head_only_elides_body() {
        let resp = Response::text("hello");
        let bytes = resp.encode(false, true);
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.to_lowercase().contains("content-length: 5"));
        assert!(text.ends_with("\r\n\r\n"));
    }

    #[test]
    fn headers_case_insensitive() {
        let mut h = Headers::new();
        h.set("Content-Type", "application/json");
        assert_eq!(h.get("content-type"), Some("application/json"));
        assert_eq!(h.get("CONTENT-TYPE"), Some("application/json"));
    }
}

//! Thread-pool HTTP/1.1 server with keep-alive and graceful shutdown.
//!
//! Architecture mirrors the role of "a scalable set of Uvicorn instances"
//! in the paper: an accept loop hands connections to a fixed pool of
//! worker threads; each worker owns its connection for its lifetime
//! (keep-alive), parsing pipelined requests incrementally and dispatching
//! them through the shared [`Router`].

use super::message::{parse_request, Deferred, ParseState, MAX_HEAD_BYTES};
use super::{Method, Response, Router};
use crate::obs::{self, ReqId, Tracer};
use crate::sync::MutexExt;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Generation-counting wakeup primitive shared between event producers
/// (the coordinator's view registry) and the parked-reader pump.
///
/// `notify_all` bumps a generation counter and wakes every waiter;
/// `wait_changed` blocks until the generation moves past a previously
/// observed value or a timeout elapses. Reading the generation *before*
/// polling state and then waiting on that snapshot closes the classic
/// lost-wakeup race: a notification landing between the poll and the
/// wait changes the generation, so the wait returns immediately.
pub struct Notify {
    generation: Mutex<u64>,
    cond: Condvar,
}

impl Notify {
    pub fn new() -> Self {
        Notify { generation: Mutex::new(0), cond: Condvar::new() }
    }

    /// Bump the generation and wake all waiters.
    pub fn notify_all(&self) {
        let mut g = self.generation.lock_safe();
        *g = g.wrapping_add(1);
        self.cond.notify_all();
    }

    /// Current generation; pass to [`Notify::wait_changed`].
    pub fn generation(&self) -> u64 {
        *self.generation.lock_safe()
    }

    /// Block until the generation differs from `seen` or `timeout`
    /// elapses; returns the generation observed on wakeup.
    pub fn wait_changed(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.generation.lock_safe();
        while *g == seen {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self
                .cond
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            g = guard;
        }
        *g
    }
}

impl Default for Notify {
    fn default() -> Self {
        Notify::new()
    }
}

impl std::fmt::Debug for Notify {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Notify").field("generation", &self.generation()).finish()
    }
}

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Worker threads handling connections (`--http-workers`).
    pub workers: usize,
    /// Per-read socket timeout; a keep-alive connection idling longer is
    /// closed.
    pub read_timeout: Duration,
    /// Upper bound on queued (accepted but unhandled) connections
    /// (`--http-backlog`). **Enforced by shedding**: when every worker
    /// owns a connection and the queue is full, new connections receive
    /// `503 Connection: close` immediately instead of waiting
    /// unboundedly behind a saturated pool — a fleet burst beyond
    /// capacity gets an explicit back-off signal, not a hung socket.
    pub backlog: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            // Each worker owns one keep-alive connection for its
            // lifetime, so `workers` bounds the number of *concurrent
            // clients*, not CPU parallelism — keep it well above any
            // realistic fleet size (threads are cheap; blocked ones cost
            // only stack). The paper's fleet was "more than twenty"
            // nodes; 128 leaves 5× headroom.
            workers: 128,
            read_timeout: Duration::from_secs(30),
            backlog: 1024,
        }
    }
}

/// Counters exposed for tests/metrics.
#[derive(Default)]
pub struct ServerStats {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    pub protocol_errors: AtomicU64,
    /// Connections shed with 503 because the worker pool and its
    /// backlog were both full.
    pub shed: AtomicU64,
}

/// A running server.
pub struct Server {
    addr: SocketAddr,
    listener: TcpListener,
    router: Arc<Router>,
    config: ServerConfig,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    /// Wakeup source for parked (deferred) responses; see
    /// [`Server::set_waker`].
    waker: Option<Arc<Notify>>,
    /// Request-tracing subsystem; see [`Server::set_tracer`].
    tracer: Option<Arc<Tracer>>,
}

/// Handle used to address and stop a server running on its own threads.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Signal shutdown and join the accept loop. In-flight requests on
    /// worker threads finish their current response.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Poke the accept loop out of `accept()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port).
    pub fn bind(addr: &str, router: Router, config: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Server {
            addr,
            listener,
            router: Arc::new(router),
            config,
            stats: Arc::new(ServerStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
            waker: None,
            tracer: None,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Install the wakeup source the parked-reader pump listens on.
    /// Handlers returning a deferred response (long-poll) are handed to
    /// the pump, which re-polls them whenever `waker` fires (or on their
    /// deadline) — parked readers therefore never occupy a worker
    /// thread. Without a waker, deferred responses still complete, but
    /// only on the pump's heartbeat and their deadline.
    pub fn set_waker(&mut self, waker: Arc<Notify>) {
        self.waker = Some(waker);
    }

    /// Install the request-tracing subsystem. With a tracer set (and
    /// enabled), every request gets an `X-Request-Id` — taken from the
    /// client's header or generated — a [`crate::obs::SpanCtx`]
    /// installed around dispatch so lower layers can record stages, and
    /// the id echoed on the response (including long-poll responses
    /// written by the parked-reader pump).
    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = Some(tracer);
    }

    /// Start accept + worker threads; returns immediately.
    pub fn start(self) -> ServerHandle {
        let shutdown = self.shutdown.clone();
        let stats = self.stats.clone();
        let addr = self.addr;

        // Connection queue feeding the worker pool. Capacity is the
        // enforced backlog: `try_send` below sheds (503) instead of
        // blocking the accept loop, so a burst beyond the pool cannot
        // queue unboundedly in the kernel behind a stalled accept.
        // Each queued element carries the connection plus any bytes
        // already read but not yet parsed, so the parked-reader pump can
        // re-enqueue a keep-alive connection without losing pipelined
        // request data.
        let (tx, rx) = mpsc::sync_channel::<(TcpStream, Vec<u8>)>(self.config.backlog.max(1));
        let rx = Arc::new(Mutex::new(rx));

        // Parked-reader pump: handlers that return a deferred response
        // (long-poll) hand their connection here instead of blocking a
        // worker. One pump thread owns every parked connection and
        // re-polls them on waker notifications and deadlines.
        let (pump_tx, pump_rx) = mpsc::channel::<ParkedConn>();
        {
            let waker = self.waker.clone().unwrap_or_default();
            let worker_tx = tx.clone();
            let stats = self.stats.clone();
            let shutdown = self.shutdown.clone();
            std::thread::spawn(move || {
                run_parked_pump(pump_rx, worker_tx, waker, stats, shutdown)
            });
        }

        for _ in 0..self.config.workers.max(1) {
            let rx = rx.clone();
            let router = self.router.clone();
            let stats = self.stats.clone();
            let config = self.config.clone();
            let shutdown = self.shutdown.clone();
            let pump_tx = pump_tx.clone();
            let tracer = self.tracer.clone();
            std::thread::spawn(move || loop {
                let conn = {
                    let guard = rx.lock_safe();
                    guard.recv()
                };
                match conn {
                    Ok((stream, buf)) => {
                        stats.connections.fetch_add(1, Ordering::Relaxed);
                        handle_connection(
                            stream, buf, &router, &stats, &config, &shutdown, &pump_tx, &tracer,
                        );
                    }
                    Err(_) => return, // sender dropped: shutting down
                }
            });
        }
        drop(pump_tx);

        let listener = self.listener;
        let shutdown2 = self.shutdown.clone();
        let stats2 = self.stats.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if shutdown2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        // Nagle off: responses are small and latency-bound.
                        let _ = s.set_nodelay(true);
                        match tx.try_send((s, Vec::new())) {
                            Ok(()) => {}
                            Err(mpsc::TrySendError::Full((mut s, _))) => {
                                // Pool + backlog saturated: shed with an
                                // explicit 503 so the client backs off,
                                // instead of parking the accept loop and
                                // letting connections pile up unbounded.
                                stats2.shed.fetch_add(1, Ordering::Relaxed);
                                let resp = Response::error(
                                    503,
                                    "server overloaded: connection backlog full",
                                );
                                let _ = s.write_all(&resp.encode(false, false));
                                // Drain the request before closing:
                                // dropping a socket with unread data
                                // makes the OS send RST, which can
                                // destroy the 503 before the client
                                // reads it. A short read timeout also
                                // catches bytes still in flight from a
                                // remote client, while bounding how
                                // long one shed connection can stall
                                // the accept loop (~2×25 ms worst
                                // case for a trickling sender).
                                let _ = s.shutdown(Shutdown::Write);
                                let _ = s.set_read_timeout(Some(
                                    Duration::from_millis(25),
                                ));
                                let mut scratch = [0u8; 4096];
                                for _ in 0..2 {
                                    match s.read(&mut scratch) {
                                        Ok(n) if n > 0 => continue,
                                        _ => break,
                                    }
                                }
                            }
                            Err(mpsc::TrySendError::Disconnected(_)) => break,
                        }
                    }
                    Err(_) => continue,
                }
            }
            // Dropping tx unblocks all workers.
        });

        ServerHandle { addr, shutdown, stats, accept_thread: Some(accept_thread) }
    }
}

/// A connection whose handler returned a deferred (long-poll) response.
/// Owned by the pump thread until the poll resolves or its deadline
/// passes; `residual` preserves already-read pipelined bytes so the
/// connection can be re-enqueued to the worker pool afterwards.
struct ParkedConn {
    stream: TcpStream,
    residual: Vec<u8>,
    keep_alive: bool,
    head_only: bool,
    /// Request id to echo on the resolved response (tracing on). The
    /// span itself was finished at park time — it cannot follow the
    /// connection across threads — so the pump only stamps the header.
    req_id: Option<ReqId>,
    deferred: Deferred,
}

/// Pump loop: owns all parked connections. Each iteration drains newly
/// parked connections, polls every parked one (deadline-forced when
/// due), writes resolved responses, and re-enqueues live keep-alive
/// connections to the worker pool. The generation snapshot taken
/// *before* polling makes the subsequent wait race-free: an event
/// arriving mid-poll bumps the generation and the wait returns at once.
fn run_parked_pump(
    inbox: mpsc::Receiver<ParkedConn>,
    worker_tx: mpsc::SyncSender<(TcpStream, Vec<u8>)>,
    waker: Arc<Notify>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
) {
    const HEARTBEAT: Duration = Duration::from_millis(100);
    let mut parked: Vec<ParkedConn> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            // Answer every parked long-poll with its deadline semantics
            // (a final forced poll, normally an empty page) instead of
            // dropping the socket mid-park: a client that parked before
            // shutdown gets a clean terminal response, not an EOF it
            // would surface as a transport error.
            for conn in parked.drain(..) {
                let ParkedConn { mut stream, head_only, req_id, mut deferred, .. } = conn;
                let mut response = (deferred.poll)(true).unwrap_or_else(|| {
                    Response::error(503, "server shutting down")
                });
                if let Some(id) = req_id {
                    response.headers.set("x-request-id", id.as_str());
                }
                // The server is going away: always close.
                let bytes = response.encode(false, head_only);
                let _ = stream.write_all(&bytes);
            }
            return;
        }
        let mut disconnected = false;
        loop {
            match inbox.try_recv() {
                Ok(conn) => parked.push(conn),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if disconnected && parked.is_empty() {
            return; // all workers gone and nothing left to serve
        }

        let gen = waker.generation();
        let now = Instant::now();
        let mut i = 0;
        while i < parked.len() {
            let due = now >= parked[i].deferred.deadline;
            match (parked[i].deferred.poll)(due) {
                None => {
                    debug_assert!(!due, "deferred poll must resolve at its deadline");
                    i += 1;
                }
                Some(mut response) => {
                    let conn = parked.swap_remove(i);
                    let ParkedConn { mut stream, residual, keep_alive, head_only, req_id, .. } =
                        conn;
                    if let Some(id) = req_id {
                        response.headers.set("x-request-id", id.as_str());
                    }
                    let bytes = response.encode(keep_alive, head_only);
                    if stream.write_all(&bytes).is_err() || !keep_alive {
                        continue; // drop: peer gone or close requested
                    }
                    match worker_tx.try_send((stream, residual)) {
                        Ok(()) => {}
                        Err(mpsc::TrySendError::Full(_)) => {
                            // Worker queue saturated: shed the revived
                            // connection rather than blocking the pump
                            // (and with it every other parked reader).
                            stats.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(mpsc::TrySendError::Disconnected(_)) => {}
                    }
                }
            }
        }

        let timeout = parked
            .iter()
            .map(|c| c.deferred.deadline.saturating_duration_since(now))
            .min()
            .map_or(HEARTBEAT, |d| d.min(HEARTBEAT));
        waker.wait_changed(gen, timeout.max(Duration::from_millis(1)));
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    mut stream: TcpStream,
    mut buf: Vec<u8>,
    router: &Router,
    stats: &ServerStats,
    config: &ServerConfig,
    shutdown: &AtomicBool,
    pump_tx: &mpsc::Sender<ParkedConn>,
    tracer: &Option<Arc<Tracer>>,
) {
    let _ = stream.set_read_timeout(Some(config.read_timeout));
    let mut chunk = [0u8; 16 * 1024];

    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Parse as many pipelined requests as the buffer holds.
        loop {
            match parse_request(&buf) {
                ParseState::Done { request, used } => {
                    buf.drain(..used);
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let keep_alive = request
                        .headers
                        .get("connection")
                        .map(|c| !c.eq_ignore_ascii_case("close"))
                        .unwrap_or(true);
                    let head_only = request.method == Method::Head;
                    // Open a span around dispatch: lower layers record
                    // stages into it through the thread-local slot, and
                    // the id is echoed on the response below.
                    let traced = tracer.as_ref().filter(|t| t.enabled());
                    let mut req_id: Option<ReqId> = None;
                    if let Some(t) = traced {
                        let span = t.begin(
                            request.headers.get("x-request-id"),
                            obs::classify(request.method.as_str(), &request.path),
                        );
                        req_id = Some(span.id());
                        obs::install(span);
                    }
                    let mut response = dispatch_safely(router, &request);
                    if let Some(mut deferred) = response.deferred.take() {
                        // Long-poll: park the connection on the pump
                        // instead of blocking this worker. One
                        // immediate poll catches events that landed
                        // between the handler's registration and now.
                        let resolved = (deferred.poll)(false);
                        match resolved {
                            Some(r) => response = r,
                            None => {
                                // The span cannot follow the connection
                                // to the pump thread: close it over the
                                // synchronous (registration) part.
                                if let Some(t) = traced {
                                    if let Some(span) = obs::take() {
                                        t.finish(span, response.status);
                                    }
                                }
                                let residual = std::mem::take(&mut buf);
                                let parked = ParkedConn {
                                    stream,
                                    residual,
                                    keep_alive,
                                    head_only,
                                    req_id,
                                    deferred,
                                };
                                match pump_tx.send(parked) {
                                    Ok(()) => return, // pump owns it now
                                    Err(mpsc::SendError(p)) => {
                                        // Pump gone (shutdown): resolve
                                        // at the deadline semantics.
                                        let mut d = p.deferred;
                                        stream = p.stream;
                                        buf = p.residual;
                                        response = (d.poll)(true)
                                            .unwrap_or_else(|| Response::error(
                                                503,
                                                "server shutting down",
                                            ));
                                    }
                                }
                            }
                        }
                    }
                    // Finish the span (drains the thread-local slot; a
                    // no-op when it already closed at park time) and
                    // echo the request id before encoding.
                    if let Some(t) = traced {
                        if let Some(span) = obs::take() {
                            t.finish(span, response.status);
                        }
                    }
                    if let Some(id) = req_id {
                        response.headers.set("x-request-id", id.as_str());
                    }
                    let bytes = response.encode(keep_alive, head_only);
                    if stream.write_all(&bytes).is_err() {
                        return;
                    }
                    if !keep_alive {
                        return;
                    }
                }
                ParseState::Bad { status, msg } => {
                    stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let resp = Response::error(status, msg);
                    let _ = stream.write_all(&resp.encode(false, false));
                    return;
                }
                ParseState::Partial => break,
            }
        }
        if buf.len() > MAX_HEAD_BYTES + super::message::MAX_BODY_BYTES {
            let _ = stream.write_all(&Response::error(413, "request too large").encode(false, false));
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                // Idle keep-alive connection timed out.
                if !buf.is_empty() {
                    let _ = stream.write_all(&Response::error(408, "request timeout").encode(false, false));
                }
                return;
            }
            Err(_) => return,
        }
    }
}

/// Catch handler panics and convert to 500 so one bad request cannot
/// take down a worker thread.
fn dispatch_safely(router: &Router, request: &super::Request) -> Response {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| router.dispatch(request))) {
        Ok(resp) => resp,
        Err(_) => Response::error(500, "internal server error"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::{Client, Request};
    use crate::json::Value;

    fn test_server(workers: usize) -> ServerHandle {
        let mut router = Router::new();
        router.get("/ping", |_, _| Response::text("pong"));
        router.post("/echo", |req: &Request, _| {
            Response::text(req.body_str().unwrap_or(""))
        });
        router.get("/json", |_, _| {
            let mut o = Value::obj();
            o.set("n", 7);
            Response::json(&Value::Obj(o))
        });
        router.get("/panic", |_, _| panic!("boom"));
        let cfg = ServerConfig { workers, ..Default::default() };
        Server::bind("127.0.0.1:0", router, cfg).unwrap().start()
    }

    #[test]
    fn serves_get() {
        let h = test_server(2);
        let mut c = Client::connect(h.addr()).unwrap();
        let r = c.get("/ping").unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body, b"pong");
        h.stop();
    }

    #[test]
    fn serves_post_echo() {
        let h = test_server(2);
        let mut c = Client::connect(h.addr()).unwrap();
        let r = c.post("/echo", b"hello body").unwrap();
        assert_eq!(r.body, b"hello body");
        h.stop();
    }

    #[test]
    fn keep_alive_multiple_requests_one_connection() {
        let h = test_server(1);
        let mut c = Client::connect(h.addr()).unwrap();
        for i in 0..10 {
            let r = c.get("/ping").unwrap();
            assert_eq!(r.status, 200, "request {i}");
        }
        assert_eq!(h.stats().connections.load(Ordering::Relaxed), 1);
        assert_eq!(h.stats().requests.load(Ordering::Relaxed), 10);
        h.stop();
    }

    #[test]
    fn concurrent_clients() {
        let h = test_server(4);
        let addr = h.addr();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    for _ in 0..20 {
                        let r = c.get("/json").unwrap();
                        assert_eq!(r.status, 200);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.stats().requests.load(Ordering::Relaxed), 160);
        h.stop();
    }

    #[test]
    fn handler_panic_becomes_500() {
        let h = test_server(2);
        let mut c = Client::connect(h.addr()).unwrap();
        let r = c.get("/panic").unwrap();
        assert_eq!(r.status, 500);
        // Connection still usable afterwards.
        let r2 = c.get("/ping").unwrap();
        assert_eq!(r2.status, 200);
        h.stop();
    }

    #[test]
    fn backlog_overflow_sheds_with_503() {
        // One worker, one backlog slot: the third concurrent connection
        // must be shed with 503 instead of queueing unboundedly.
        let mut router = Router::new();
        router.get("/ping", |_, _| Response::text("pong"));
        let cfg = ServerConfig { workers: 1, backlog: 1, ..Default::default() };
        let h = Server::bind("127.0.0.1:0", router, cfg).unwrap().start();

        // c1: served a request, so the lone worker now owns it.
        let mut c1 = Client::connect(h.addr()).unwrap();
        assert_eq!(c1.get("/ping").unwrap().status, 200);
        // c2: accepted into the single backlog slot.
        let c2 = TcpStream::connect(h.addr()).unwrap();
        // Give the accept loop a beat to queue c2.
        std::thread::sleep(std::time::Duration::from_millis(50));
        // c3: pool busy + backlog full → immediate 503, connection closed.
        let mut c3 = TcpStream::connect(h.addr()).unwrap();
        let mut out = String::new();
        c3.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 503"), "got: {out}");
        assert!(out.contains("overloaded"), "got: {out}");
        assert!(h.stats().shed.load(Ordering::Relaxed) >= 1);

        // Draining c1 frees the worker: the queued c2 is then served.
        drop(c1);
        let mut c2 = c2;
        c2.write_all(b"GET /ping HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        c2.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"), "queued connection served: {out}");
        h.stop();
    }

    #[test]
    fn malformed_request_gets_400_and_close() {
        let h = test_server(1);
        let mut s = TcpStream::connect(h.addr()).unwrap();
        s.write_all(b"NOT AN HTTP REQUEST\r\n\r\n").unwrap();
        let mut out = String::new();
        let _ = s.read_to_string(&mut out);
        assert!(out.starts_with("HTTP/1.1 400"), "got: {out}");
        h.stop();
    }

    #[test]
    fn stop_unblocks() {
        let h = test_server(2);
        let addr = h.addr();
        h.stop();
        // Subsequent connections may connect (OS may accept) but requests
        // should not be served; just assert no hang on stop and a fresh
        // bind to the port range still works.
        let _ = TcpStream::connect(addr);
    }
}

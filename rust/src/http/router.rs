//! Method+path router with `{param}` captures.
//!
//! Routes are matched segment-wise; `{name}` captures one segment. On a
//! path match with the wrong method the router answers 405 (with an
//! `allow` header), otherwise 404 — matching FastAPI behaviour, which is
//! what the paper's clients are written against.

use super::{Method, Request, Response};
use std::collections::HashMap;
use std::sync::Arc;

/// Captured path parameters.
#[derive(Clone, Debug, Default)]
pub struct PathParams {
    map: HashMap<String, String>,
}

impl PathParams {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.map.get(name).map(|s| s.as_str())
    }
}

type Handler = dyn Fn(&Request, &PathParams) -> Response + Send + Sync;

struct Route {
    method: Method,
    segments: Vec<Seg>,
    handler: Arc<Handler>,
}

enum Seg {
    Lit(String),
    Param(String),
}

/// The router. Cheap to clone via `Arc` at the server layer.
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Self {
        Router::default()
    }

    /// Register a route, e.g. `route(Method::Post, "/api/ask/{token}", h)`.
    pub fn route(
        &mut self,
        method: Method,
        pattern: &str,
        handler: impl Fn(&Request, &PathParams) -> Response + Send + Sync + 'static,
    ) -> &mut Self {
        let segments = pattern
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| {
                if s.starts_with('{') && s.ends_with('}') {
                    Seg::Param(s[1..s.len() - 1].to_string())
                } else {
                    Seg::Lit(s.to_string())
                }
            })
            .collect();
        self.routes.push(Route { method, segments, handler: Arc::new(handler) });
        self
    }

    pub fn get(
        &mut self,
        pattern: &str,
        handler: impl Fn(&Request, &PathParams) -> Response + Send + Sync + 'static,
    ) -> &mut Self {
        self.route(Method::Get, pattern, handler)
    }

    pub fn post(
        &mut self,
        pattern: &str,
        handler: impl Fn(&Request, &PathParams) -> Response + Send + Sync + 'static,
    ) -> &mut Self {
        self.route(Method::Post, pattern, handler)
    }

    /// Dispatch a request.
    pub fn dispatch(&self, req: &Request) -> Response {
        let path_segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        let mut allowed: Vec<&str> = Vec::new();
        for route in &self.routes {
            if let Some(params) = match_segments(&route.segments, &path_segs) {
                // HEAD is served by the GET handler; the server elides
                // the body at encode time.
                let method_matches = route.method == req.method
                    || (req.method == Method::Head && route.method == Method::Get);
                if method_matches {
                    return (route.handler)(req, &params);
                }
                allowed.push(route.method.as_str());
            }
        }
        if !allowed.is_empty() {
            allowed.sort();
            allowed.dedup();
            let mut resp = Response::error(405, "method not allowed");
            resp.headers.set("allow", allowed.join(", "));
            return resp;
        }
        Response::error(404, "not found")
    }
}

fn match_segments(pattern: &[Seg], path: &[&str]) -> Option<PathParams> {
    if pattern.len() != path.len() {
        return None;
    }
    let mut params = PathParams::default();
    for (seg, part) in pattern.iter().zip(path) {
        match seg {
            Seg::Lit(lit) if lit == part => {}
            Seg::Lit(_) => return None,
            Seg::Param(name) => {
                params.map.insert(name.clone(), part.to_string());
            }
        }
    }
    Some(params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::Headers;

    fn req(method: Method, path: &str) -> Request {
        Request {
            method,
            path: path.to_string(),
            query: String::new(),
            headers: Headers::new(),
            body: Vec::new(),
        }
    }

    fn router() -> Router {
        let mut r = Router::new();
        r.get("/api/version", |_, _| Response::text("v"));
        r.post("/api/ask/{token}", |_, p| {
            Response::text(&format!("ask:{}", p.get("token").unwrap()))
        });
        r.get("/api/studies/{id}/trials/{tid}", |_, p| {
            Response::text(&format!("{}:{}", p.get("id").unwrap(), p.get("tid").unwrap()))
        });
        r
    }

    #[test]
    fn literal_match() {
        let r = router();
        let resp = r.dispatch(&req(Method::Get, "/api/version"));
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"v");
    }

    #[test]
    fn param_capture() {
        let r = router();
        let resp = r.dispatch(&req(Method::Post, "/api/ask/abc123"));
        assert_eq!(resp.body, b"ask:abc123");
    }

    #[test]
    fn multi_param_capture() {
        let r = router();
        let resp = r.dispatch(&req(Method::Get, "/api/studies/s1/trials/t9"));
        assert_eq!(resp.body, b"s1:t9");
    }

    #[test]
    fn not_found() {
        let r = router();
        assert_eq!(r.dispatch(&req(Method::Get, "/nope")).status, 404);
        assert_eq!(r.dispatch(&req(Method::Get, "/api/ask")).status, 404);
        // Too many segments.
        assert_eq!(r.dispatch(&req(Method::Post, "/api/ask/a/b")).status, 404);
    }

    #[test]
    fn wrong_method_is_405_with_allow() {
        let r = router();
        let resp = r.dispatch(&req(Method::Get, "/api/ask/tok"));
        assert_eq!(resp.status, 405);
        assert_eq!(resp.headers.get("allow"), Some("POST"));
    }

    #[test]
    fn head_served_by_get() {
        let r = router();
        let resp = r.dispatch(&req(Method::Head, "/api/version"));
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn trailing_slash_equivalent() {
        // Segment-wise matching ignores empty segments, so a trailing
        // slash resolves to the same route.
        let r = router();
        assert_eq!(r.dispatch(&req(Method::Get, "/api/version/")).status, 200);
    }
}

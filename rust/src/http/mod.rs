//! HTTP/1.1 substrate.
//!
//! The paper's deployment stack is Uvicorn (ASGI workers) behind an NGINX
//! reverse proxy. Offline, we implement the part of that stack the
//! HOPAAS protocol actually needs: a correct, concurrent HTTP/1.1 server
//! with keep-alive and a thread-pool accept loop (the analog of "a
//! scalable set of Uvicorn instances"), plus a blocking client used by
//! the Rust HOPAAS worker fleet and the test/bench harnesses.
//!
//! Scope: `Content-Length` bodies (the HOPAAS APIs never stream),
//! request-size limits, per-connection read timeouts, `HEAD` handling,
//! and graceful shutdown. TLS is out of scope (the paper terminates HTTPS
//! at NGINX, i.e. outside the application) — see DESIGN.md §3.

mod client;
mod message;
mod router;
mod server;

pub use client::{Client, ClientError};
pub use message::{
    parse_request, read_request, Deferred, Headers, Method, ParseState, Request, Response,
};
pub use router::{PathParams, Router};
pub use server::{Notify, Server, ServerConfig, ServerHandle};

/// Canonical reason phrases for the status codes the service emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        204 => "No Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

//! Minimal blocking HTTP/1.1 client with keep-alive and auto-reconnect.
//!
//! Used by the Rust HOPAAS worker fleet (the analog of the paper's Python
//! client package [12]) and by tests/benches.

use super::{Headers, Response};
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Client error type.
#[derive(Debug, thiserror::Error)]
pub enum ClientError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("protocol error: {0}")]
    Protocol(String),
}

/// A keep-alive connection to one server.
pub struct Client {
    addr: SocketAddr,
    stream: Option<BufReader<TcpStream>>,
    timeout: Duration,
}

impl Client {
    /// Connect (lazily re-connects on broken connections).
    pub fn connect(addr: SocketAddr) -> Result<Self, ClientError> {
        let mut c = Client { addr, stream: None, timeout: Duration::from_secs(30) };
        c.ensure_connected()?;
        Ok(c)
    }

    /// Set per-operation socket timeout.
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
        if let Some(s) = &self.stream {
            let _ = s.get_ref().set_read_timeout(Some(timeout));
            let _ = s.get_ref().set_write_timeout(Some(timeout));
        }
    }

    fn ensure_connected(&mut self) -> Result<(), ClientError> {
        if self.stream.is_none() {
            let s = TcpStream::connect_timeout(&self.addr, self.timeout)?;
            s.set_nodelay(true)?;
            s.set_read_timeout(Some(self.timeout))?;
            s.set_write_timeout(Some(self.timeout))?;
            self.stream = Some(BufReader::new(s));
        }
        Ok(())
    }

    /// GET `path`.
    pub fn get(&mut self, path: &str) -> Result<Response, ClientError> {
        self.request("GET", path, &[], None)
    }

    /// POST raw bytes.
    pub fn post(&mut self, path: &str, body: &[u8]) -> Result<Response, ClientError> {
        self.request("POST", path, &[("content-type", "application/octet-stream")], Some(body))
    }

    /// POST a JSON value.
    pub fn post_json(
        &mut self,
        path: &str,
        value: &crate::json::Value,
    ) -> Result<Response, ClientError> {
        let body = value.to_string().into_bytes();
        self.request("POST", path, &[("content-type", "application/json")], Some(&body))
    }

    /// Issue a request; one transparent retry on a stale keep-alive
    /// connection (server closed between requests).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> Result<Response, ClientError> {
        match self.try_request(method, path, headers, body) {
            Ok(r) => Ok(r),
            Err(ClientError::Io(e))
                if matches!(
                    e.kind(),
                    ErrorKind::BrokenPipe
                        | ErrorKind::ConnectionReset
                        | ErrorKind::UnexpectedEof
                        | ErrorKind::ConnectionAborted
                ) =>
            {
                self.stream = None;
                self.try_request(method, path, headers, body)
            }
            Err(e) => Err(e),
        }
    }

    fn try_request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> Result<Response, ClientError> {
        self.ensure_connected()?;
        let reader = self.stream.as_mut().unwrap();
        let stream = reader.get_mut();

        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: hopaas\r\n");
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("content-length: {}\r\n\r\n", body.map_or(0, |b| b.len())));
        let mut msg = head.into_bytes();
        if let Some(b) = body {
            msg.extend_from_slice(b);
        }
        let write_res = stream.write_all(&msg);
        if let Err(e) = write_res {
            return Err(ClientError::Io(e));
        }

        read_response(reader)
    }
}

/// Read one HTTP/1.1 response (status line, headers, Content-Length body).
fn read_response(reader: &mut BufReader<TcpStream>) -> Result<Response, ClientError> {
    let mut status_line = String::new();
    let n = reader.read_line(&mut status_line)?;
    if n == 0 {
        return Err(ClientError::Io(std::io::Error::new(
            ErrorKind::UnexpectedEof,
            "connection closed before status line",
        )));
    }
    let status_line = status_line.trim_end();
    let mut parts = status_line.splitn(3, ' ');
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(ClientError::Protocol(format!("bad status line: {status_line}")));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| ClientError::Protocol("missing status code".into()))?;

    let mut headers = Headers::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.set(k.trim(), v.trim().to_string());
        }
    }

    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Response { status, headers, body, deferred: None })
}

impl Response {
    /// Parse the body as JSON.
    pub fn json_body(&self) -> Result<crate::json::Value, ClientError> {
        let s = std::str::from_utf8(&self.body)
            .map_err(|_| ClientError::Protocol("non-utf8 body".into()))?;
        crate::json::parse(s).map_err(|e| ClientError::Protocol(e.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::{Router, Server, ServerConfig};

    #[test]
    fn reconnects_after_server_side_close() {
        let mut router = Router::new();
        router.get("/once", |_, _| {
            let mut r = Response::text("only");
            // Ask the server to close after this response.
            r.headers.set("connection", "close");
            r
        });
        router.get("/ok", |_, _| Response::text("ok"));
        let h = Server::bind("127.0.0.1:0", router, ServerConfig::default())
            .unwrap()
            .start();
        let mut c = Client::connect(h.addr()).unwrap();
        // Note: our server keeps the connection according to the REQUEST's
        // connection header, so simulate staleness by dropping the stream.
        let r = c.get("/ok").unwrap();
        assert_eq!(r.status, 200);
        c.stream = None; // simulate stale / reset connection
        let r2 = c.get("/ok").unwrap();
        assert_eq!(r2.status, 200);
        h.stop();
    }

    #[test]
    fn json_body_parse() {
        let mut router = Router::new();
        router.get("/j", |_, _| {
            let mut o = crate::json::Value::obj();
            o.set("x", 1.5);
            Response::json(&crate::json::Value::Obj(o))
        });
        let h = Server::bind("127.0.0.1:0", router, ServerConfig::default())
            .unwrap()
            .start();
        let mut c = Client::connect(h.addr()).unwrap();
        let v = c.get("/j").unwrap().json_body().unwrap();
        assert_eq!(v.get("x").as_f64(), Some(1.5));
        h.stop();
    }
}

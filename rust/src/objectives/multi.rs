//! Multi-objective benchmark problems (ZDT suite, Zitzler et al. 2000)
//! for the MO extension (paper §5 future work). All are bi-objective
//! minimization over `[0, 1]^d` with known Pareto fronts, which makes
//! hypervolume-based comparisons exact.

use crate::json::Value;

/// A bi-objective test problem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MoProblem {
    /// Convex front: f2 = 1 − √f1.
    Zdt1,
    /// Concave front: f2 = 1 − f1².
    Zdt2,
    /// Disconnected front.
    Zdt3,
}

pub const ALL_MO: [MoProblem; 3] = [MoProblem::Zdt1, MoProblem::Zdt2, MoProblem::Zdt3];

impl MoProblem {
    pub fn name(&self) -> &'static str {
        match self {
            MoProblem::Zdt1 => "zdt1",
            MoProblem::Zdt2 => "zdt2",
            MoProblem::Zdt3 => "zdt3",
        }
    }

    /// Decision-space dimensionality (standard is 30; 8 keeps bench
    /// budgets small while preserving the front geometry).
    pub fn dim(&self) -> usize {
        8
    }

    /// Evaluate both objectives at `x ∈ [0,1]^d`.
    pub fn eval(&self, x: &[f64]) -> [f64; 2] {
        let f1 = x[0];
        let g = 1.0 + 9.0 * x[1..].iter().sum::<f64>() / (x.len() - 1) as f64;
        let h = match self {
            MoProblem::Zdt1 => 1.0 - (f1 / g).sqrt(),
            MoProblem::Zdt2 => 1.0 - (f1 / g) * (f1 / g),
            MoProblem::Zdt3 => {
                1.0 - (f1 / g).sqrt() - (f1 / g) * (10.0 * std::f64::consts::PI * f1).sin()
            }
        };
        [f1, g * h]
    }

    /// HOPAAS `properties` for the decision space.
    pub fn properties(&self) -> Value {
        let mut o = Value::obj();
        for i in 0..self.dim() {
            let mut spec = Value::obj();
            spec.set("low", 0.0).set("high", 1.0);
            o.set(format!("x{i}"), Value::Obj(spec));
        }
        Value::Obj(o)
    }

    /// Evaluate from a HOPAAS params object.
    pub fn eval_params(&self, params: &Value) -> [f64; 2] {
        let x: Vec<f64> = (0..self.dim())
            .map(|i| params.get(&format!("x{i}")).as_f64().unwrap_or(0.0))
            .collect();
        self.eval(&x)
    }

    /// Reference point for hypervolume (all fronts fit under it).
    pub fn hv_reference(&self) -> [f64; 2] {
        [1.1, 11.0]
    }

    pub fn by_name(name: &str) -> Option<MoProblem> {
        ALL_MO.iter().copied().find(|p| p.name() == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_front_at_g_one() {
        // On the true front, all tail variables are 0 (g = 1).
        let mut x = vec![0.0; 8];
        x[0] = 0.25;
        let [f1, f2] = MoProblem::Zdt1.eval(&x);
        assert_eq!(f1, 0.25);
        assert!((f2 - (1.0 - 0.25f64.sqrt())).abs() < 1e-12);
        let [_, f2b] = MoProblem::Zdt2.eval(&x);
        assert!((f2b - (1.0 - 0.0625)).abs() < 1e-12);
    }

    #[test]
    fn off_front_dominated() {
        // Raising a tail variable worsens f2 at the same f1.
        let mut on = vec![0.0; 8];
        on[0] = 0.5;
        let mut off = on.clone();
        off[3] = 0.8;
        for p in ALL_MO {
            let a = p.eval(&on);
            let b = p.eval(&off);
            assert_eq!(a[0], b[0]);
            assert!(a[1] < b[1], "{}: {} !< {}", p.name(), a[1], b[1]);
        }
    }

    #[test]
    fn zdt2_front_concave_zdt1_convex() {
        // Midpoint test: convex front lies below the line between
        // endpoints, concave above.
        let front = |p: MoProblem, f1: f64| {
            let mut x = vec![0.0; 8];
            x[0] = f1;
            p.eval(&x)[1]
        };
        let mid1 = front(MoProblem::Zdt1, 0.5);
        let mid2 = front(MoProblem::Zdt2, 0.5);
        assert!(mid1 < 0.5, "zdt1 convex: {mid1}");
        assert!(mid2 > 0.5, "zdt2 concave: {mid2}");
    }

    #[test]
    fn properties_parse() {
        for p in ALL_MO {
            let space =
                crate::coordinator::space::Space::from_json(&p.properties()).unwrap();
            assert_eq!(space.len(), p.dim());
        }
        assert_eq!(MoProblem::by_name("zdt2"), Some(MoProblem::Zdt2));
        assert_eq!(MoProblem::by_name("x"), None);
    }
}

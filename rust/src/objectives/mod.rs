//! Synthetic benchmark objectives for the sampler (E4) and pruner (E5)
//! studies — the standard black-box optimization test functions, plus
//! parameterized learning-curve simulators that let pruner experiments
//! run thousands of "trainings" without touching the GAN.

pub mod multi;

use crate::json::Value;
use crate::rng::Rng;

/// A black-box objective over a fixed-dimension continuous domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Σ x² — unimodal sanity check. Domain [-5, 5]^d, min 0 at origin.
    Sphere,
    /// Branin-Hoo (2-D), three global minima, f* ≈ 0.397887.
    Branin,
    /// Rosenbrock valley. Domain [-2, 2]^d, min 0 at (1, ..., 1).
    Rosenbrock,
    /// Ackley — deceptive flat outer region. Domain [-5, 5]^d, min 0.
    Ackley,
    /// Rastrigin — highly multimodal. Domain [-5.12, 5.12]^d, min 0.
    Rastrigin,
    /// Styblinski-Tang. Domain [-5, 5]^d, min ≈ -39.166·d.
    StyblinskiTang,
    /// Hartmann 6-D, min ≈ -3.32237.
    Hartmann6,
}

pub const ALL: [Objective; 7] = [
    Objective::Sphere,
    Objective::Branin,
    Objective::Rosenbrock,
    Objective::Ackley,
    Objective::Rastrigin,
    Objective::StyblinskiTang,
    Objective::Hartmann6,
];

impl Objective {
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Sphere => "sphere",
            Objective::Branin => "branin",
            Objective::Rosenbrock => "rosenbrock",
            Objective::Ackley => "ackley",
            Objective::Rastrigin => "rastrigin",
            Objective::StyblinskiTang => "styblinski_tang",
            Objective::Hartmann6 => "hartmann6",
        }
    }

    /// Natural dimensionality (fixed for Branin/Hartmann; default for
    /// the scalable ones).
    pub fn dim(&self) -> usize {
        match self {
            Objective::Branin => 2,
            Objective::Hartmann6 => 6,
            _ => 4,
        }
    }

    /// Domain per dimension.
    pub fn bounds(&self) -> (f64, f64) {
        match self {
            Objective::Branin => (-5.0, 15.0), // x1 ∈ [-5,10], x2 ∈ [0,15]: superset box
            Objective::Rosenbrock => (-2.0, 2.0),
            Objective::Rastrigin => (-5.12, 5.12),
            Objective::Hartmann6 => (0.0, 1.0),
            _ => (-5.0, 5.0),
        }
    }

    /// Known global minimum value (for regret computation).
    pub fn f_star(&self) -> f64 {
        match self {
            Objective::Branin => 0.397887,
            Objective::StyblinskiTang => -39.16599 * self.dim() as f64,
            Objective::Hartmann6 => -3.32237,
            _ => 0.0,
        }
    }

    /// Evaluate at `x` (length = `dim()`).
    pub fn eval(&self, x: &[f64]) -> f64 {
        match self {
            Objective::Sphere => x.iter().map(|v| v * v).sum(),
            Objective::Branin => {
                let (x1, x2) = (x[0], x[1]);
                let a = 1.0;
                let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
                let c = 5.0 / std::f64::consts::PI;
                let r = 6.0;
                let s = 10.0;
                let t = 1.0 / (8.0 * std::f64::consts::PI);
                a * (x2 - b * x1 * x1 + c * x1 - r).powi(2)
                    + s * (1.0 - t) * x1.cos()
                    + s
            }
            Objective::Rosenbrock => x
                .windows(2)
                .map(|w| 100.0 * (w[1] - w[0] * w[0]).powi(2) + (1.0 - w[0]).powi(2))
                .sum(),
            Objective::Ackley => {
                let d = x.len() as f64;
                let s1: f64 = x.iter().map(|v| v * v).sum::<f64>() / d;
                let s2: f64 = x
                    .iter()
                    .map(|v| (2.0 * std::f64::consts::PI * v).cos())
                    .sum::<f64>()
                    / d;
                -20.0 * (-0.2 * s1.sqrt()).exp() - s2.exp() + 20.0 + std::f64::consts::E
            }
            Objective::Rastrigin => {
                10.0 * x.len() as f64
                    + x.iter()
                        .map(|v| v * v - 10.0 * (2.0 * std::f64::consts::PI * v).cos())
                        .sum::<f64>()
            }
            Objective::StyblinskiTang => {
                0.5 * x
                    .iter()
                    .map(|v| v.powi(4) - 16.0 * v * v + 5.0 * v)
                    .sum::<f64>()
            }
            Objective::Hartmann6 => {
                const A: [[f64; 6]; 4] = [
                    [10.0, 3.0, 17.0, 3.5, 1.7, 8.0],
                    [0.05, 10.0, 17.0, 0.1, 8.0, 14.0],
                    [3.0, 3.5, 1.7, 10.0, 17.0, 8.0],
                    [17.0, 8.0, 0.05, 10.0, 0.1, 14.0],
                ];
                const P: [[f64; 6]; 4] = [
                    [0.1312, 0.1696, 0.5569, 0.0124, 0.8283, 0.5886],
                    [0.2329, 0.4135, 0.8307, 0.3736, 0.1004, 0.9991],
                    [0.2348, 0.1451, 0.3522, 0.2883, 0.3047, 0.6650],
                    [0.4047, 0.8828, 0.8732, 0.5743, 0.1091, 0.0381],
                ];
                const ALPHA: [f64; 4] = [1.0, 1.2, 3.0, 3.2];
                -(0..4)
                    .map(|i| {
                        let inner: f64 = (0..6)
                            .map(|j| A[i][j] * (x[j] - P[i][j]).powi(2))
                            .sum();
                        ALPHA[i] * (-inner).exp()
                    })
                    .sum::<f64>()
            }
        }
    }

    /// The HOPAAS `properties` object for this objective's search space.
    pub fn properties(&self) -> Value {
        let (lo, hi) = self.bounds();
        let mut o = Value::obj();
        for i in 0..self.dim() {
            let mut spec = Value::obj();
            spec.set("low", lo).set("high", hi);
            o.set(format!("x{i}"), Value::Obj(spec));
        }
        Value::Obj(o)
    }

    /// Evaluate from a HOPAAS params object.
    pub fn eval_params(&self, params: &Value) -> f64 {
        let x: Vec<f64> = (0..self.dim())
            .map(|i| params.get(&format!("x{i}")).as_f64().unwrap_or(0.0))
            .collect();
        self.eval(&x)
    }

    /// Parse by name.
    pub fn by_name(name: &str) -> Option<Objective> {
        ALL.iter().copied().find(|o| o.name() == name)
    }
}

/// Additive-Gaussian-noise wrapper: the "noisy loss function" setting the
/// paper motivates BO with (§1).
pub struct Noisy {
    pub inner: Objective,
    pub sigma: f64,
}

impl Noisy {
    pub fn eval(&self, x: &[f64], rng: &mut Rng) -> f64 {
        self.inner.eval(x) + rng.normal() * self.sigma
    }
}

/// A simulated training curve for pruner studies (E5): loss decays
/// exponentially from `start` to an asymptote determined by the trial's
/// hyperparameter quality, with observation noise. Good hyperparameters
/// → low asymptote; the pruner's job is to kill high-asymptote curves
/// early.
#[derive(Clone, Debug)]
pub struct LearningCurve {
    pub asymptote: f64,
    pub start: f64,
    pub rate: f64,
    pub noise: f64,
}

impl LearningCurve {
    /// Build from a quality score in [0, 1] (0 = best hyperparameters).
    pub fn from_quality(quality: f64, rng: &mut Rng) -> LearningCurve {
        LearningCurve {
            asymptote: 0.1 + 2.0 * quality,
            start: 3.0 + rng.f64(),
            rate: 0.05 + 0.1 * rng.f64(),
            noise: 0.02,
        }
    }

    /// Loss at integer step `t ≥ 1`.
    pub fn at(&self, t: u64, rng: &mut Rng) -> f64 {
        let decay = (-self.rate * t as f64).exp();
        self.asymptote + (self.start - self.asymptote) * decay + rng.normal() * self.noise
    }

    /// Final converged loss (expected value, no noise).
    pub fn final_loss(&self) -> f64 {
        self.asymptote
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn known_minima() {
        assert_eq!(Objective::Sphere.eval(&[0.0; 4]), 0.0);
        assert!((Objective::Rosenbrock.eval(&[1.0; 4])).abs() < 1e-12);
        assert!(Objective::Ackley.eval(&[0.0; 4]).abs() < 1e-9);
        assert_eq!(Objective::Rastrigin.eval(&[0.0; 4]), 0.0);
        // Branin at one of its minima.
        let v = Objective::Branin.eval(&[std::f64::consts::PI, 2.275]);
        assert!((v - 0.397887).abs() < 1e-4, "branin={v}");
        // Hartmann6 optimum.
        let v = Objective::Hartmann6.eval(&[0.20169, 0.150011, 0.476874, 0.275332, 0.311652, 0.6573]);
        assert!((v + 3.32237).abs() < 1e-3, "hartmann={v}");
        // Styblinski-Tang per-dim optimum at -2.903534.
        let v = Objective::StyblinskiTang.eval(&[-2.903534; 4]);
        assert!((v - Objective::StyblinskiTang.f_star()).abs() < 1e-2);
    }

    #[test]
    fn minima_are_local_minima() {
        // Perturbing the known optimum must not improve any objective.
        let cases: Vec<(Objective, Vec<f64>)> = vec![
            (Objective::Sphere, vec![0.0; 4]),
            (Objective::Rosenbrock, vec![1.0; 4]),
            (Objective::Ackley, vec![0.0; 4]),
            (Objective::Rastrigin, vec![0.0; 4]),
        ];
        prop::check(100, |g| {
            let (obj, xstar) = &cases[g.rng().below(cases.len() as u64) as usize];
            let mut x = xstar.clone();
            let i = g.rng().below(x.len() as u64) as usize;
            x[i] += g.f64(-0.01, 0.01);
            prop::assert_holds(
                obj.eval(&x) >= obj.eval(xstar) - 1e-9,
                format!("{:?} improved off-optimum", obj.name()),
            )
        });
    }

    #[test]
    fn properties_roundtrip_to_space() {
        for obj in ALL {
            let space =
                crate::coordinator::space::Space::from_json(&obj.properties()).unwrap();
            assert_eq!(space.len(), obj.dim());
            let mut rng = Rng::new(4);
            let asg = space.sample(&mut rng);
            let params = crate::coordinator::space::assignment_to_json(&asg);
            let v = obj.eval_params(&params);
            assert!(v.is_finite(), "{}: {v}", obj.name());
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for obj in ALL {
            assert_eq!(Objective::by_name(obj.name()), Some(obj));
        }
        assert_eq!(Objective::by_name("nope"), None);
    }

    #[test]
    fn noisy_wrapper_centers_on_truth() {
        let noisy = Noisy { inner: Objective::Sphere, sigma: 0.5 };
        let mut rng = Rng::new(8);
        let n = 5000;
        let mean: f64 =
            (0..n).map(|_| noisy.eval(&[1.0, 0.0, 0.0, 0.0], &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn learning_curve_monotone_expectation() {
        let mut rng = Rng::new(1);
        let c = LearningCurve::from_quality(0.2, &mut rng);
        // Expected loss decreases with t (check noiseless backbone).
        let noiseless =
            |t: u64| c.asymptote + (c.start - c.asymptote) * (-c.rate * t as f64).exp();
        assert!(noiseless(1) > noiseless(10));
        assert!(noiseless(10) > noiseless(100));
        assert!((noiseless(10_000) - c.final_loss()).abs() < 1e-6);
    }

    #[test]
    fn curve_quality_orders_final_loss() {
        let mut rng = Rng::new(2);
        let good = LearningCurve::from_quality(0.05, &mut rng);
        let bad = LearningCurve::from_quality(0.9, &mut rng);
        assert!(good.final_loss() < bad.final_loss());
    }
}

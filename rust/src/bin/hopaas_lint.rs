//! `hopaas-lint` — the repo's concurrency-correctness linter.
//!
//! ```text
//! cargo run --bin hopaas-lint                  # report findings
//! cargo run --bin hopaas-lint -- --deny        # CI gate: fail on new/stale
//! cargo run --bin hopaas-lint -- --write-baseline
//! cargo run --bin hopaas-lint -- --hierarchy   # print the lock table
//! ```
//!
//! Exit codes: 0 clean (or informational run), 1 policy violation
//! under `--deny` (new finding or stale baseline entry), 2 usage or
//! I/O error.

use hopaas::analysis::{self, baseline, Finding, HIERARCHY, RULES};
use std::path::PathBuf;
use std::process::ExitCode;

struct Opts {
    root: Option<PathBuf>,
    baseline: Option<PathBuf>,
    deny: bool,
    write_baseline: bool,
    report: Option<PathBuf>,
    hierarchy: bool,
}

fn usage() -> &'static str {
    "usage: hopaas-lint [--root SRC_DIR] [--baseline FILE] [--deny] \
     [--write-baseline] [--report FILE] [--hierarchy]"
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        root: None,
        baseline: None,
        deny: false,
        write_baseline: false,
        report: None,
        hierarchy: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => opts.root = Some(args.next().ok_or("--root needs a value")?.into()),
            "--baseline" => {
                opts.baseline = Some(args.next().ok_or("--baseline needs a value")?.into());
            }
            "--deny" => opts.deny = true,
            "--write-baseline" => opts.write_baseline = true,
            "--report" => opts.report = Some(args.next().ok_or("--report needs a value")?.into()),
            "--hierarchy" => opts.hierarchy = true,
            "--help" | "-h" => return Err(String::new()),
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(opts)
}

fn print_hierarchy() {
    println!("canonical lock hierarchy (acquire in ascending level order):\n");
    for c in HIERARCHY {
        println!("  {:>3}  {:<13} receivers: {}", c.level, c.name, c.receivers.join(", "));
        println!("       {}", c.doc);
    }
    println!("\nrules: {}", RULES.join(", "));
    println!("suppress with `// lint:allow(<rule>): <reason>` on or above the line");
}

fn render_report(findings: &[Finding], diff: &baseline::Diff, deny: bool) -> String {
    let mut out = String::new();
    out.push_str("hopaas-lint report\n==================\n\n");
    if findings.is_empty() {
        out.push_str("no findings.\n");
        return out;
    }
    for rule in RULES {
        let of_rule: Vec<&&Finding> = diff.new.iter().filter(|f| f.rule == *rule).collect();
        if of_rule.is_empty() {
            continue;
        }
        out.push_str(&format!("[{rule}] — {} new finding(s)\n", of_rule.len()));
        for f in of_rule {
            out.push_str(&format!("  {}\n", f.render()));
        }
        out.push('\n');
    }
    if !diff.stale.is_empty() {
        out.push_str(&format!("stale baseline entries ({}) — delete them:\n", diff.stale.len()));
        for k in &diff.stale {
            out.push_str(&format!("  {k}\n"));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "total: {} finding(s), {} baselined, {} new, {} stale{}\n",
        findings.len(),
        diff.baselined,
        diff.new.len(),
        diff.stale.len(),
        if deny { " (--deny)" } else { "" },
    ));
    out
}

fn run() -> Result<ExitCode, String> {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) if e.is_empty() => {
            println!("{}", usage());
            return Ok(ExitCode::SUCCESS);
        }
        Err(e) => return Err(format!("{e}\n{}", usage())),
    };

    if opts.hierarchy {
        print_hierarchy();
        return Ok(ExitCode::SUCCESS);
    }

    let root = match opts.root.or_else(analysis::default_src_root) {
        Some(r) => r,
        None => return Err("cannot locate src/ — pass --root".into()),
    };
    let baseline_path =
        opts.baseline.unwrap_or_else(|| analysis::default_baseline_path(&root));

    let findings =
        analysis::lint_tree(&root).map_err(|e| format!("scanning {}: {e}", root.display()))?;

    if opts.write_baseline {
        std::fs::write(&baseline_path, baseline::render(&findings))
            .map_err(|e| format!("writing {}: {e}", baseline_path.display()))?;
        println!(
            "wrote {} key(s) to {}",
            findings.len(),
            baseline_path.display()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let base = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => baseline::parse(&text),
        Err(_) => Default::default(),
    };
    let diff = baseline::diff(&findings, &base);
    let report = render_report(&findings, &diff, opts.deny);
    print!("{report}");
    if let Some(path) = &opts.report {
        std::fs::write(path, &report).map_err(|e| format!("writing {}: {e}", path.display()))?;
    }

    if opts.deny && (!diff.new.is_empty() || !diff.stale.is_empty()) {
        eprintln!(
            "hopaas-lint: --deny: {} new finding(s), {} stale baseline entr(ies)",
            diff.new.len(),
            diff.stale.len()
        );
        return Ok(ExitCode::FAILURE);
    }
    Ok(ExitCode::SUCCESS)
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("hopaas-lint: {e}");
            ExitCode::from(2)
        }
    }
}

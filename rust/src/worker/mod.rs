//! The client side: a Rust HOPAAS client wrapping the REST APIs (the
//! analog of the paper's Python frontend [12]) and a multi-site node
//! simulator reproducing the paper's §4 fleet — INFN Cloud, CINECA
//! MARCONI 100, private and commercial nodes with different speeds,
//! availability windows and preemption behaviour.

pub mod client;
pub mod sim;

pub use client::{HopaasClient, StudySpec, TrialHandle, WorkerError};
pub use sim::{Campaign, CampaignReport, NodeProfile, Site, SITES};

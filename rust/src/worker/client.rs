//! Rust HOPAAS client — wraps the Table 1 REST APIs, mirroring the
//! ergonomics of the paper's Python client: build a `StudySpec`, `ask`
//! for a `TrialHandle`, stream intermediate values through
//! `should_prune`, finish with `tell`.
//!
//! ```no_run
//! use hopaas::worker::{HopaasClient, StudySpec};
//! let mut client = HopaasClient::connect("127.0.0.1:8021".parse().unwrap(),
//!                                        "TOKEN".into()).unwrap();
//! let spec = StudySpec::new("demo")
//!     .uniform("x", -5.0, 5.0)
//!     .loguniform("lr", 1e-5, 1e-1)
//!     .sampler("tpe");
//! let trial = client.ask(&spec).unwrap();
//! let x = trial.params.get("x").as_f64().unwrap();
//! client.tell(&trial, x * x).unwrap();
//! ```

use crate::http::{Client, ClientError};
use crate::json::Value;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};

/// Client-side errors, including HTTP error envelopes.
#[derive(Debug, thiserror::Error)]
pub enum WorkerError {
    #[error("transport: {0}")]
    Transport(#[from] ClientError),
    #[error("server returned {status}: {detail} (request {})", .request_id.as_deref().unwrap_or("-"))]
    Api {
        status: u16,
        detail: String,
        /// `X-Request-Id` of the failing call — quote it to the server
        /// operator: `GET /api/trace/{id}` recovers the full per-stage
        /// timeline of exactly this request.
        request_id: Option<String>,
    },
}

/// Process-wide client instance counter: keeps per-operation request
/// ids unique across the many clients a campaign spawns in one process.
static CLIENT_SEQ: AtomicU64 = AtomicU64::new(1);

/// Retry policy for transport failures and 503 rejections: capped
/// exponential backoff with jitter, so a worker fleet riding through a
/// primary restart or a follower promotion doesn't stampede the new
/// primary in lockstep.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries before the error surfaces (0 disables failover).
    pub attempts: u32,
    /// First backoff delay.
    pub base_ms: u64,
    /// Backoff ceiling.
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { attempts: 6, base_ms: 50, cap_ms: 2000 }
    }
}

/// Extract a connectable address from a `primary` hint (`host:port`,
/// optionally scheme-prefixed / path-suffixed).
fn parse_primary_hint(hint: &str) -> Option<SocketAddr> {
    let hint = hint.strip_prefix("http://").or_else(|| hint.strip_prefix("https://")).unwrap_or(hint);
    let hint = hint.split('/').next().unwrap_or(hint);
    hint.parse().ok()
}

/// Declarative study definition (what the `ask` body carries).
#[derive(Clone, Debug)]
pub struct StudySpec {
    pub name: String,
    pub direction: &'static str,
    /// Multi-objective directions (overrides `direction` when set).
    mo_directions: Option<Vec<String>>,
    properties: Value,
    sampler: Option<Value>,
    pruner: Option<Value>,
    pub node: Option<String>,
}

impl StudySpec {
    pub fn new(name: &str) -> StudySpec {
        StudySpec {
            name: name.to_string(),
            direction: "minimize",
            mo_directions: None,
            properties: Value::Obj(crate::json::Value::obj()),
            sampler: None,
            pruner: None,
            node: None,
        }
    }

    fn prop(mut self, key: &str, spec: Value) -> Self {
        if let Value::Obj(o) = &mut self.properties {
            o.set(key, spec);
        }
        self
    }

    /// Continuous uniform parameter.
    pub fn uniform(self, key: &str, low: f64, high: f64) -> Self {
        let mut s = Value::obj();
        s.set("low", low).set("high", high);
        self.prop(key, Value::Obj(s))
    }

    /// Log-uniform parameter.
    pub fn loguniform(self, key: &str, low: f64, high: f64) -> Self {
        let mut s = Value::obj();
        s.set("low", low).set("high", high).set("type", "loguniform");
        self.prop(key, Value::Obj(s))
    }

    /// Integer parameter.
    pub fn int(self, key: &str, low: i64, high: i64) -> Self {
        let mut s = Value::obj();
        s.set("low", low).set("high", high).set("type", "int");
        self.prop(key, Value::Obj(s))
    }

    /// Categorical parameter.
    pub fn categorical(self, key: &str, choices: Vec<Value>) -> Self {
        self.prop(key, Value::Arr(choices))
    }

    /// Raw properties object (e.g. from `Objective::properties`).
    pub fn properties_json(mut self, props: Value) -> Self {
        self.properties = props;
        self
    }

    pub fn maximize(mut self) -> Self {
        self.direction = "maximize";
        self
    }

    /// Multi-objective study: per-objective directions (≥ 2). The
    /// sampler defaults to NSGA-II; `tell` must use [`HopaasClient::
    /// tell_values`].
    pub fn directions(mut self, dirs: &[&str]) -> Self {
        self.mo_directions = Some(dirs.iter().map(|d| d.to_string()).collect());
        self
    }

    /// Sampler by name.
    pub fn sampler(mut self, name: &str) -> Self {
        let mut s = Value::obj();
        s.set("name", name);
        self.sampler = Some(Value::Obj(s));
        self
    }

    /// Sampler with options.
    pub fn sampler_json(mut self, cfg: Value) -> Self {
        self.sampler = Some(cfg);
        self
    }

    /// Pruner by name.
    pub fn pruner(mut self, name: &str) -> Self {
        let mut s = Value::obj();
        s.set("name", name);
        self.pruner = Some(Value::Obj(s));
        self
    }

    /// Pruner with options.
    pub fn pruner_json(mut self, cfg: Value) -> Self {
        self.pruner = Some(cfg);
        self
    }

    /// Node label for dashboard attribution.
    pub fn from_node(mut self, node: &str) -> Self {
        self.node = Some(node.to_string());
        self
    }

    /// The `ask` request body.
    pub fn to_body(&self) -> Value {
        let mut o = Value::obj();
        o.set("study_name", self.name.as_str())
            .set("properties", self.properties.clone());
        match &self.mo_directions {
            Some(ds) => o.set(
                "direction",
                Value::Arr(ds.iter().map(|d| Value::Str(d.clone())).collect()),
            ),
            None => o.set("direction", self.direction),
        };
        if let Some(s) = &self.sampler {
            o.set("sampler", s.clone());
        }
        if let Some(p) = &self.pruner {
            o.set("pruner", p.clone());
        }
        if let Some(n) = &self.node {
            o.set("node", n.as_str());
        }
        Value::Obj(o)
    }
}

/// A live trial returned by `ask`.
#[derive(Clone, Debug)]
pub struct TrialHandle {
    pub trial_id: u64,
    pub trial_number: u64,
    pub study_id: u64,
    pub params: Value,
    /// True when this trial was originally handed to a worker that was
    /// lost and has been re-assigned to us via its lease expiry.
    pub requeued: bool,
    /// `X-Request-Id` of the `ask` that delivered this trial (client-
    /// generated, echoed by the server). Recoverable server-side via
    /// `GET /api/trace/{id}`; requeued trials carry the id of the ask
    /// that re-delivered them, not the original worker's.
    pub request_id: Option<String>,
}

/// Blocking HOPAAS client over one keep-alive connection.
pub struct HopaasClient {
    http: Client,
    /// Where the next reconnect goes; updated when a read-only follower
    /// answers 503 with a `primary` hint.
    addr: SocketAddr,
    retry: RetryPolicy,
    token: String,
    /// Fleet worker identity, set by [`HopaasClient::register_worker`];
    /// when present every `ask` is lease-bound to it.
    worker_id: Option<u64>,
    /// Declared tenant identity for `--no-auth` servers (dev, benches,
    /// the campaign simulator). Against an authenticated server the
    /// token's user claim is the tenant and this field is ignored
    /// server-side — it cannot be used to spoof another tenant.
    tenant: Option<String>,
    /// This client's slot in [`CLIENT_SEQ`] plus a per-client counter:
    /// together with the pid they mint collision-free request ids.
    nonce: u64,
    seq: u64,
    last_request_id: Option<String>,
}

impl HopaasClient {
    pub fn connect(addr: SocketAddr, token: String) -> Result<HopaasClient, WorkerError> {
        Ok(HopaasClient {
            http: Client::connect(addr)?,
            addr,
            retry: RetryPolicy::default(),
            token,
            worker_id: None,
            tenant: None,
            nonce: CLIENT_SEQ.fetch_add(1, Ordering::Relaxed),
            seq: 0,
            last_request_id: None,
        })
    }

    /// Declare a tenant identity on asks (effective only against
    /// `--no-auth` servers; see the `tenant` field docs).
    pub fn as_tenant(mut self, tenant: &str) -> Self {
        self.tenant = Some(tenant.to_string());
        self
    }

    /// Set the declared tenant in place (simulator nodes switch
    /// identities without rebuilding the connection).
    pub fn set_tenant(&mut self, tenant: Option<String>) {
        self.tenant = tenant;
    }

    /// Override the failover policy (`attempts: 0` surfaces transport
    /// errors and 503s immediately — what assertion-heavy tests want).
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// The address operations currently target (follows `primary`
    /// hints across a promotion).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn check(resp: crate::http::Response) -> Result<Value, WorkerError> {
        // GETs don't send an id; the server may still have generated and
        // echoed one worth surfacing on errors.
        let request_id = resp.headers.get("x-request-id").map(str::to_string);
        Self::check_with(resp, request_id)
    }

    fn check_with(
        resp: crate::http::Response,
        request_id: Option<String>,
    ) -> Result<Value, WorkerError> {
        let body = resp.json_body().unwrap_or(Value::Null);
        if resp.status != 200 {
            return Err(WorkerError::Api {
                status: resp.status,
                detail: body.get("detail").as_str().unwrap_or("?").to_string(),
                request_id,
            });
        }
        Ok(body)
    }

    /// Mint the `X-Request-Id` for the next operation.
    fn next_request_id(&mut self) -> String {
        self.seq += 1;
        format!("wkr-{}-{}-{}", std::process::id(), self.nonce, self.seq)
    }

    /// Sleep the current backoff step (plus jitter) and double it up to
    /// the cap.
    fn backoff(&self, delay_ms: &mut u64) {
        let jitter = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64)
            .unwrap_or(0)
            % (*delay_ms / 2 + 1);
        std::thread::sleep(std::time::Duration::from_millis(*delay_ms + jitter));
        *delay_ms = (*delay_ms * 2).min(self.retry.cap_ms.max(1));
    }

    /// Tear down the keep-alive connection and redial `self.addr`. A
    /// failed dial is left for the next attempt's request to surface.
    fn reconnect(&mut self) {
        if let Ok(h) = Client::connect(self.addr) {
            self.http = h;
        }
    }

    /// POST with an `X-Request-Id` attached. The transport's transparent
    /// retry on a stale keep-alive connection re-sends the same header
    /// set, so one id names one logical operation across retries and the
    /// server's trace buffer dedupes nothing.
    ///
    /// Transport failures and 503 answers (a restarting primary, or a
    /// read-only follower during a promotion) are retried with capped
    /// exponential backoff + jitter, re-sending the *same* request id —
    /// the retries are one logical operation, and the trace a campaign
    /// operator pulls afterwards names whichever server finally served
    /// it. A follower's `{"primary": ...}` hint redirects the redial.
    fn post_traced(&mut self, path: &str, value: &Value) -> Result<Value, WorkerError> {
        let rid = self.next_request_id();
        let body = value.to_string().into_bytes();
        let mut attempt = 0u32;
        let mut delay_ms = self.retry.base_ms.max(1);
        loop {
            let result = self.http.request(
                "POST",
                path,
                &[("content-type", "application/json"), ("x-request-id", &rid)],
                Some(&body),
            );
            let resp = match result {
                Ok(resp) => resp,
                Err(_) if attempt < self.retry.attempts => {
                    attempt += 1;
                    self.backoff(&mut delay_ms);
                    self.reconnect();
                    continue;
                }
                Err(e) => return Err(e.into()),
            };
            if resp.status == 503 && attempt < self.retry.attempts {
                let hint_body = resp.json_body().unwrap_or(Value::Null);
                if let Some(hint) = hint_body.get("primary").as_str() {
                    if let Some(addr) = parse_primary_hint(hint) {
                        self.addr = addr;
                    }
                }
                attempt += 1;
                self.backoff(&mut delay_ms);
                self.reconnect();
                continue;
            }
            // Prefer the echoed id (the server sanitizes); keep what we
            // sent when tracing is disabled server-side.
            let echoed = resp.headers.get("x-request-id").map(str::to_string);
            self.last_request_id = Some(echoed.unwrap_or_else(|| rid.clone()));
            return Self::check_with(resp, self.last_request_id.clone());
        }
    }

    /// `X-Request-Id` of the most recent traced operation, as echoed by
    /// the server.
    pub fn last_request_id(&self) -> Option<&str> {
        self.last_request_id.as_deref()
    }

    /// Server version string.
    pub fn version(&mut self) -> Result<String, WorkerError> {
        let v = Self::check(self.http.get("/api/version")?)?;
        Ok(v.get("version").as_str().unwrap_or("").to_string())
    }

    /// Register this client as a fleet worker: every subsequent `ask`
    /// binds its trial to the worker's heartbeat lease. Returns the
    /// worker id; `heartbeat` must be called within the server's lease
    /// timeout or the worker's trials are requeued to others.
    pub fn register_worker(
        &mut self,
        name: &str,
        site: &str,
        gpu: &str,
    ) -> Result<u64, WorkerError> {
        let path = format!("/api/workers/register/{}", self.token);
        let mut o = Value::obj();
        o.set("name", name).set("site", site).set("gpu", gpu);
        let v = self.post_traced(&path, &Value::Obj(o))?;
        let id = v.get("worker_id").as_u64().unwrap_or(0);
        self.worker_id = Some(id);
        Ok(id)
    }

    /// Renew this worker's lease; returns how many trials it covers.
    pub fn heartbeat(&mut self) -> Result<u64, WorkerError> {
        let Some(wid) = self.worker_id else {
            return Err(WorkerError::Api {
                status: 0,
                detail: "not registered as a worker".into(),
                request_id: None,
            });
        };
        let path = format!("/api/workers/heartbeat/{}", self.token);
        let mut o = Value::obj();
        o.set("worker_id", wid);
        let v = self.post_traced(&path, &Value::Obj(o))?;
        Ok(v.get("leases").as_u64().unwrap_or(0))
    }

    /// Graceful shutdown: hand running trials back for reassignment.
    /// The worker identity is only dropped once the server has answered
    /// — a transport error leaves it in place so the call can be
    /// retried. A 404/409 (unknown, or already declared lost) also
    /// clears it: that identity is no longer usable either way.
    pub fn deregister_worker(&mut self) -> Result<u64, WorkerError> {
        let Some(wid) = self.worker_id else { return Ok(0) };
        let path = format!("/api/workers/deregister/{}", self.token);
        let mut o = Value::obj();
        o.set("worker_id", wid);
        match self.post_traced(&path, &Value::Obj(o)) {
            Ok(v) => {
                self.worker_id = None;
                Ok(v.get("requeued").as_u64().unwrap_or(0))
            }
            Err(WorkerError::Api { status: 404 | 409, .. }) => {
                self.worker_id = None;
                Ok(0)
            }
            Err(e) => Err(e),
        }
    }

    /// Worker identity, if registered.
    pub fn worker_id(&self) -> Option<u64> {
        self.worker_id
    }

    /// Drop the worker identity client-side (simulating a vanished spot
    /// instance: no deregister, no goodbye — the server's lease expiry
    /// must notice).
    pub fn abandon_worker(&mut self) {
        self.worker_id = None;
    }

    /// The `ask` body for `spec` with this client's worker/tenant
    /// identity attached.
    fn ask_request(&self, spec: &StudySpec) -> Value {
        let mut body = spec.to_body();
        if let (Some(wid), Value::Obj(o)) = (self.worker_id, &mut body) {
            o.set("worker", wid);
        }
        if let (Some(t), Value::Obj(o)) = (&self.tenant, &mut body) {
            o.set("tenant", t.as_str());
        }
        body
    }

    fn trial_handle(v: &Value) -> TrialHandle {
        TrialHandle {
            trial_id: v.get("trial_id").as_u64().unwrap_or(0),
            trial_number: v.get("trial_number").as_u64().unwrap_or(0),
            study_id: v.get("study_id").as_u64().unwrap_or(0),
            params: v.get("params").clone(),
            requeued: v.get("requeued").as_bool().unwrap_or(false),
            request_id: None,
        }
    }

    /// `ask`: join/create the study, receive a trial (a fresh one, or a
    /// requeued trial whose previous worker was lost).
    pub fn ask(&mut self, spec: &StudySpec) -> Result<TrialHandle, WorkerError> {
        let path = format!("/api/ask/{}", self.token);
        let body = self.ask_request(spec);
        let v = self.post_traced(&path, &body)?;
        let mut t = Self::trial_handle(&v);
        t.request_id = self.last_request_id.clone();
        Ok(t)
    }

    /// Batched `ask`: request up to `n` trials in one round trip (one
    /// admission pass and one sampler fit server-side). The server may
    /// return fewer than `n` under per-tenant quota pressure; at least
    /// one trial is returned on success.
    pub fn ask_n(&mut self, spec: &StudySpec, n: usize) -> Result<Vec<TrialHandle>, WorkerError> {
        let path = format!("/api/ask/{}", self.token);
        let mut body = self.ask_request(spec);
        if let Value::Obj(o) = &mut body {
            o.set("n", n as u64);
        }
        let v = self.post_traced(&path, &body)?;
        let trials = v.get("trials").as_arr().unwrap_or(&[]);
        // One round trip, one admission pass, one trace: every trial in
        // the batch shares the ask's request id.
        Ok(trials
            .iter()
            .map(|tv| {
                let mut t = Self::trial_handle(tv);
                t.request_id = self.last_request_id.clone();
                t
            })
            .collect())
    }

    /// `tell`: finalize with the objective value. Returns `is_best`.
    pub fn tell(&mut self, trial: &TrialHandle, value: f64) -> Result<bool, WorkerError> {
        let path = format!("/api/tell/{}", self.token);
        let mut o = Value::obj();
        o.set("trial_id", trial.trial_id).set("value", value);
        let v = self.post_traced(&path, &Value::Obj(o))?;
        Ok(v.get("is_best").as_bool().unwrap_or(false))
    }

    /// `tell` for multi-objective studies. Returns `on_pareto_front`.
    pub fn tell_values(
        &mut self,
        trial: &TrialHandle,
        values: &[f64],
    ) -> Result<bool, WorkerError> {
        let path = format!("/api/tell/{}", self.token);
        let mut o = Value::obj();
        o.set("trial_id", trial.trial_id).set(
            "values",
            Value::Arr(values.iter().map(|&v| Value::Num(v)).collect()),
        );
        let v = self.post_traced(&path, &Value::Obj(o))?;
        Ok(v.get("on_pareto_front").as_bool().unwrap_or(false))
    }

    /// Pareto front of a multi-objective study.
    pub fn pareto(&mut self, study_id: u64) -> Result<Value, WorkerError> {
        Self::check(self.http.get(&format!("/api/studies/{study_id}/pareto"))?)
    }

    /// `should_prune`: report (step, value); true = abort the trial.
    pub fn should_prune(
        &mut self,
        trial: &TrialHandle,
        step: u64,
        value: f64,
    ) -> Result<bool, WorkerError> {
        let path = format!("/api/should_prune/{}", self.token);
        let mut o = Value::obj();
        o.set("trial_id", trial.trial_id)
            .set("step", step)
            .set("value", value);
        let v = self.post_traced(&path, &Value::Obj(o))?;
        Ok(v.get("should_prune").as_bool().unwrap_or(false))
    }

    /// Report a client-side failure.
    pub fn fail(&mut self, trial: &TrialHandle) -> Result<(), WorkerError> {
        let path = format!("/api/fail/{}", self.token);
        let mut o = Value::obj();
        o.set("trial_id", trial.trial_id);
        self.post_traced(&path, &Value::Obj(o))?;
        Ok(())
    }

    /// Study summaries (dashboard API).
    pub fn studies(&mut self) -> Result<Value, WorkerError> {
        Self::check(self.http.get("/api/studies")?)
    }

    /// One study's best value, if any.
    pub fn best_value(&mut self, study_id: u64) -> Result<Option<f64>, WorkerError> {
        let v = Self::check(self.http.get(&format!("/api/studies/{study_id}"))?)?;
        Ok(v.get("best_value").as_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{HopaasConfig, HopaasServer};

    fn server() -> HopaasServer {
        HopaasServer::start(
            "127.0.0.1:0",
            HopaasConfig { auth_required: true, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn spec_builder_body_shape() {
        let spec = StudySpec::new("s")
            .uniform("x", 0.0, 1.0)
            .loguniform("lr", 1e-5, 1e-1)
            .int("k", 1, 8)
            .categorical("opt", vec![Value::Str("adam".into())])
            .sampler("tpe")
            .pruner("median")
            .from_node("n1")
            .maximize();
        let b = spec.to_body();
        assert_eq!(b.get("direction").as_str(), Some("maximize"));
        assert_eq!(b.get("properties").get("lr").get("type").as_str(), Some("loguniform"));
        assert_eq!(b.get("sampler").get("name").as_str(), Some("tpe"));
        assert_eq!(b.get("node").as_str(), Some("n1"));
    }

    #[test]
    fn end_to_end_optimize_sphere() {
        let s = server();
        let mut c = HopaasClient::connect(s.addr(), s.bootstrap_token.clone()).unwrap();
        assert_eq!(c.version().unwrap(), crate::VERSION);
        let spec = StudySpec::new("sphere")
            .uniform("x", -5.0, 5.0)
            .sampler("tpe");
        let mut best = f64::INFINITY;
        let mut study_id = 0;
        for _ in 0..30 {
            let t = c.ask(&spec).unwrap();
            study_id = t.study_id;
            let x = t.params.get("x").as_f64().unwrap();
            let v = x * x;
            best = best.min(v);
            c.tell(&t, v).unwrap();
        }
        assert_eq!(c.best_value(study_id).unwrap(), Some(best));
        assert!(best < 2.0, "TPE on 1-D sphere after 30 trials: {best}");
        s.stop();
    }

    #[test]
    fn api_error_surfaces() {
        let s = server();
        let mut c = HopaasClient::connect(s.addr(), "bogus".into()).unwrap();
        let spec = StudySpec::new("x").uniform("x", 0.0, 1.0);
        match c.ask(&spec) {
            Err(WorkerError::Api { status: 401, request_id: Some(rid), .. }) => {
                // The error carries the id we sent, echoed by the server.
                assert!(rid.starts_with("wkr-"), "{rid}");
            }
            other => panic!("expected 401 with request id, got {other:?}"),
        }
        s.stop();
    }

    #[test]
    fn request_ids_attach_to_trials_and_traces() {
        let s = server();
        let mut c = HopaasClient::connect(s.addr(), s.bootstrap_token.clone()).unwrap();
        let spec = StudySpec::new("rid").uniform("x", 0.0, 1.0).sampler("random");
        let t = c.ask(&spec).unwrap();
        let rid = t.request_id.clone().expect("ask carries its request id");
        assert!(rid.starts_with("wkr-"), "{rid}");
        assert_eq!(c.last_request_id(), Some(rid.as_str()));
        // The id names a recoverable server-side trace of exactly that ask.
        let trace = s.engine.tracer().get(&rid).expect("trace retained");
        assert_eq!(trace.get("kind").as_str(), Some("ask"));
        // Each operation mints a fresh id.
        c.tell(&t, 1.0).unwrap();
        let tell_rid = c.last_request_id().unwrap().to_string();
        assert_ne!(tell_rid, rid);
        let trace = s.engine.tracer().get(&tell_rid).expect("tell trace retained");
        assert_eq!(trace.get("kind").as_str(), Some("tell"));
        s.stop();
    }

    #[test]
    fn worker_lease_flow() {
        let s = server();
        let mut c = HopaasClient::connect(s.addr(), s.bootstrap_token.clone()).unwrap();
        let wid = c.register_worker("n1", "infn-cloud", "a100").unwrap();
        assert_eq!(c.worker_id(), Some(wid));
        let spec = StudySpec::new("lease").uniform("x", 0.0, 1.0).sampler("random");
        let t = c.ask(&spec).unwrap();
        assert!(!t.requeued);
        assert_eq!(c.heartbeat().unwrap(), 1, "ask bound one lease");
        c.tell(&t, 1.0).unwrap();
        assert_eq!(c.heartbeat().unwrap(), 0, "tell released it");
        assert_eq!(c.deregister_worker().unwrap(), 0);
        assert_eq!(c.worker_id(), None);
        s.stop();
    }

    #[test]
    fn batched_ask_round_trip() {
        let s = server();
        let mut c = HopaasClient::connect(s.addr(), s.bootstrap_token.clone()).unwrap();
        c.register_worker("n1", "cloud", "gpu").unwrap();
        let spec = StudySpec::new("batch").uniform("x", 0.0, 1.0).sampler("random");
        let trials = c.ask_n(&spec, 4).unwrap();
        assert_eq!(trials.len(), 4);
        let numbers: Vec<u64> = trials.iter().map(|t| t.trial_number).collect();
        assert_eq!(numbers, vec![0, 1, 2, 3]);
        assert_eq!(c.heartbeat().unwrap(), 4, "each batched trial holds a lease");
        for t in &trials {
            c.tell(t, t.params.get("x").as_f64().unwrap()).unwrap();
        }
        assert_eq!(c.heartbeat().unwrap(), 0);
        s.stop();
    }

    #[test]
    fn tenant_identity_on_no_auth_servers() {
        let config = HopaasConfig {
            auth_required: false,
            engine: crate::coordinator::engine::EngineConfig {
                tenant_quota: 1,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = HopaasServer::start("127.0.0.1:0", config).unwrap();
        let mut c = HopaasClient::connect(s.addr(), "t".into())
            .unwrap()
            .as_tenant("alice");
        c.register_worker("n1", "cloud", "gpu").unwrap();
        let spec = StudySpec::new("tq").uniform("x", 0.0, 1.0).sampler("random");
        let t1 = c.ask(&spec).unwrap();
        // One lease held, tenant quota 1: the denial names the tenant.
        match c.ask(&spec) {
            Err(WorkerError::Api { status: 429, detail, .. }) => {
                assert!(detail.contains("alice"), "{detail}");
            }
            other => panic!("expected tenant 429, got {other:?}"),
        }
        c.tell(&t1, 1.0).unwrap();
        let t2 = c.ask(&spec).unwrap();
        c.tell(&t2, 2.0).unwrap();
        s.stop();
    }

    #[test]
    fn prune_flow() {
        let s = server();
        let mut c = HopaasClient::connect(s.addr(), s.bootstrap_token.clone()).unwrap();
        let spec = StudySpec::new("p")
            .uniform("x", 0.0, 1.0)
            .pruner_json({
                let mut p = Value::obj();
                p.set("name", "threshold").set("upper", 10.0);
                Value::Obj(p)
            });
        let t = c.ask(&spec).unwrap();
        assert!(!c.should_prune(&t, 1, 1.0).unwrap());
        assert!(c.should_prune(&t, 2, 100.0).unwrap(), "over threshold");
        // After pruning, tell conflicts.
        match c.tell(&t, 1.0) {
            Err(WorkerError::Api { status: 409, .. }) => {}
            other => panic!("expected 409, got {other:?}"),
        }
        s.stop();
    }
}

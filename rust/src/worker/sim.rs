//! Multi-site node simulator — the stand-in for the paper's fleet.
//!
//! §4 of the paper: "HOPAAS was able to coordinate dozens of optimization
//! studies with hundreds of trials on each study from more than twenty
//! concurrent and diverse computing nodes" spanning CINECA MARCONI 100,
//! INFN Cloud, private machines and commercial clouds. We cannot rent
//! MARCONI 100 (repro band 0), but the coordination behaviour under test
//! depends only on the *timing envelope* of the nodes: how fast they
//! iterate, how often they vanish mid-trial (opportunistic preemption),
//! and how jittery their network is. [`Site`] profiles encode exactly
//! that, and [`Campaign`] runs a fleet of worker threads against a real
//! HOPAAS server over real HTTP.
//!
//! Each simulated node runs the Figure 1 loop: `ask` → (train step,
//! `should_prune`)* → `tell`, evaluating a synthetic objective whose
//! learning curve reflects the quality of the suggested hyperparameters
//! — so samplers and pruners face the same statistical problem a GAN
//! campaign poses, thousands of times faster.

use super::client::{HopaasClient, StudySpec, WorkerError};
use crate::objectives::{LearningCurve, Objective};
use crate::rng::{mix, Rng};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A resource-provider profile (speed × reliability × latency).
#[derive(Clone, Copy, Debug)]
pub struct Site {
    pub name: &'static str,
    /// Relative step speed (1.0 = reference GPU).
    pub speed: f64,
    /// Probability that a trial is preempted before finishing.
    pub preempt: f64,
    /// Simulated per-request network latency (µs).
    pub net_latency_us: u64,
}

/// The paper's §4 mix: HPC, institutional cloud, private boxes,
/// commercial spot instances.
pub const SITES: [Site; 4] = [
    Site { name: "marconi100", speed: 2.0, preempt: 0.02, net_latency_us: 800 },
    Site { name: "infn-cloud", speed: 1.0, preempt: 0.01, net_latency_us: 300 },
    Site { name: "private", speed: 0.5, preempt: 0.00, net_latency_us: 100 },
    Site { name: "commercial-spot", speed: 1.5, preempt: 0.15, net_latency_us: 1200 },
];

/// One simulated node.
#[derive(Clone, Debug)]
pub struct NodeProfile {
    pub site: Site,
    pub node_id: usize,
}

impl NodeProfile {
    pub fn label(&self) -> String {
        format!("{}-{:02}", self.site.name, self.node_id)
    }
}

/// Campaign configuration.
#[derive(Clone)]
pub struct Campaign {
    pub server: SocketAddr,
    pub token: String,
    pub study_name: String,
    pub objective: Objective,
    pub sampler: &'static str,
    /// Pruner name, or None.
    pub pruner: Option<&'static str>,
    /// Nodes per site (cycled through SITES).
    pub n_nodes: usize,
    /// Stop once this many trials have been *started* campaign-wide.
    pub max_trials: u64,
    /// Steps per (unpruned) trial.
    pub steps_per_trial: u64,
    /// Simulated work per step at speed 1.0 (µs). 0 = as fast as possible.
    pub step_cost_us: u64,
    pub seed: u64,
    /// Use the fleet protocol: nodes register as workers, heartbeat
    /// every step, and vanish without a goodbye on preemption — their
    /// trials come back via server-side lease expiry, not the reaper.
    /// A preempted node re-registers as a fresh worker (a respawned
    /// spot instance). The caller must drive `Engine::expire_leases`
    /// (the serve loop does in production).
    pub fleet: bool,
    /// Tenant identities cycled over the nodes (node `i` runs as
    /// `tenants[i % len]`), exercising per-tenant quotas: a multi-user
    /// campaign against a `--no-auth` server declares the identity on
    /// each ask; against an authenticated server, put per-user tokens
    /// in `token` per campaign instead. Empty = tenant-less (the
    /// pre-policy behavior).
    pub tenants: Vec<String>,
    /// Trials fetched per `ask` round trip (`"n": k` batched asks).
    /// 1 = the classic one-ask-one-trial loop; higher values amortize
    /// the ask round trip and the server-side sampler fit over the
    /// batch, which a multi-GPU node running k trials at once wants.
    pub ask_batch: usize,
    /// Concurrent dashboard readers running alongside the fleet: each
    /// pages `/api/studies` and every study's trials via cursors, reads
    /// `/best`, and long-polls the `/events` feed — the read-side load
    /// the materialized views exist to absorb. 0 = no readers.
    pub viewers: usize,
}

impl Campaign {
    pub fn new(server: SocketAddr, token: String, objective: Objective) -> Campaign {
        Campaign {
            server,
            token,
            study_name: format!("campaign-{}", objective.name()),
            objective,
            sampler: "tpe",
            pruner: Some("median"),
            n_nodes: 24,
            max_trials: 200,
            steps_per_trial: 20,
            step_cost_us: 200,
            seed: 1,
            fleet: false,
            tenants: Vec::new(),
            ask_batch: 1,
            viewers: 0,
        }
    }

    fn spec(&self, node: &NodeProfile) -> StudySpec {
        let mut spec = StudySpec::new(&self.study_name)
            .properties_json(self.objective.properties())
            .sampler(self.sampler)
            .from_node(&node.label());
        if let Some(p) = self.pruner {
            spec = spec.pruner(p);
        }
        spec
    }

    /// Run the fleet over the default §4 site mix; blocks until
    /// `max_trials` have been started and all in-flight trials finished.
    pub fn run(&self) -> Result<CampaignReport, WorkerError> {
        self.run_with_sites(&SITES)
    }

    /// Run the fleet over a custom site table (ablations: uniform fleets,
    /// controlled preemption rates — see the churn bench).
    pub fn run_with_sites(&self, sites: &[Site]) -> Result<CampaignReport, WorkerError> {
        let started = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let t0 = std::time::Instant::now();
        // Readers first, so they observe the campaign from its first
        // trial; they stop only after every writer has drained.
        let viewer_stop = Arc::new(AtomicBool::new(false));
        let mut viewer_handles = Vec::new();
        for v in 0..self.viewers {
            let server = self.server;
            let stop = viewer_stop.clone();
            viewer_handles.push(std::thread::spawn(move || viewer_loop(server, v, &stop)));
        }
        let mut handles = Vec::new();
        for i in 0..self.n_nodes {
            let node = NodeProfile { site: sites[i % sites.len()], node_id: i };
            let campaign = self.clone();
            let started = started.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                node_loop(&campaign, &node, &started, &stop)
            }));
        }
        let mut report = CampaignReport::default();
        for h in handles {
            let node_report = h.join().expect("node thread")?;
            report.merge(&node_report);
        }
        viewer_stop.store(true, Ordering::Relaxed);
        for h in viewer_handles {
            report.viewer_pages += h.join().unwrap_or(0);
        }
        report.wall = t0.elapsed();
        Ok(report)
    }
}

/// Per-node / aggregated campaign outcome.
#[derive(Clone, Debug, Default)]
pub struct CampaignReport {
    pub completed: u64,
    pub pruned: u64,
    pub preempted: u64,
    /// Trials received via requeue (another worker's preempted trial,
    /// re-assigned through lease expiry). Fleet mode only.
    pub requeued_taken: u64,
    pub steps_executed: u64,
    pub best: Option<f64>,
    pub wall: Duration,
    /// (site name, completed trials) attribution.
    pub by_site: Vec<(String, u64)>,
    /// Read-path pages served to the campaign's viewers (campaign-level;
    /// node reports never carry it).
    pub viewer_pages: u64,
}

impl CampaignReport {
    fn merge(&mut self, other: &CampaignReport) {
        self.completed += other.completed;
        self.pruned += other.pruned;
        self.preempted += other.preempted;
        self.requeued_taken += other.requeued_taken;
        self.steps_executed += other.steps_executed;
        self.best = match (self.best, other.best) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        for (site, n) in &other.by_site {
            match self.by_site.iter_mut().find(|(s, _)| s == site) {
                Some((_, total)) => *total += n,
                None => self.by_site.push((site.clone(), *n)),
            }
        }
    }

    /// Trials finished per second of wall time.
    pub fn throughput(&self) -> f64 {
        let total = (self.completed + self.pruned + self.preempted) as f64;
        total / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Attach fleet context to an API error so campaign failure reports
/// name the node and the server-side trace id of the failing call —
/// `GET /api/trace/{id}` on the coordinator recovers its timeline.
fn attribute(e: WorkerError, node: &NodeProfile, client: &HopaasClient) -> WorkerError {
    match e {
        WorkerError::Api { status, detail, request_id } => WorkerError::Api {
            status,
            detail: format!("{detail} (node {})", node.label()),
            request_id: request_id.or_else(|| client.last_request_id().map(str::to_string)),
        },
        other => other,
    }
}

fn node_loop(
    campaign: &Campaign,
    node: &NodeProfile,
    started: &AtomicU64,
    stop: &AtomicBool,
) -> Result<CampaignReport, WorkerError> {
    let mut rng = Rng::new(mix(campaign.seed, node.node_id as u64));
    let mut client = HopaasClient::connect(campaign.server, campaign.token.clone())?;
    if !campaign.tenants.is_empty() {
        client.set_tenant(Some(
            campaign.tenants[node.node_id % campaign.tenants.len()].clone(),
        ));
    }
    if campaign.fleet {
        client.register_worker(&node.label(), node.site.name, "sim-gpu")?;
    }
    let spec = campaign.spec(node);
    let mut report = CampaignReport::default();
    let mut site_completed = 0u64;
    let mut incarnation = 0u64;

    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let n = started.fetch_add(1, Ordering::Relaxed);
        if n >= campaign.max_trials {
            stop.store(true, Ordering::Relaxed);
            break;
        }
        net_delay(node, &mut rng);
        // Batched mode claims the extra start slots up front and fetches
        // the whole batch in one round trip; the server may answer with
        // fewer under quota pressure, in which case the unused slots are
        // returned to the pool.
        let extra = (campaign.ask_batch.max(1) as u64 - 1)
            .min(campaign.max_trials.saturating_sub(n + 1));
        if extra > 0 {
            started.fetch_add(extra, Ordering::Relaxed);
        }
        let claimed = 1 + extra;
        let result = if extra > 0 {
            client.ask_n(&spec, claimed as usize)
        } else {
            client.ask(&spec).map(|t| vec![t])
        };
        let trials = match result {
            Ok(ts) => {
                let short = claimed - ts.len() as u64;
                if short > 0 {
                    started.fetch_sub(short, Ordering::Relaxed);
                }
                ts
            }
            // Quota / fair-share denial: no slot was consumed — back
            // off briefly and retry.
            Err(WorkerError::Api { status: 429, .. }) => {
                started.fetch_sub(claimed, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            // Fleet mode: this worker was declared lost (a heartbeat
            // gap on a loaded machine). Its trials are already queued
            // for others — re-register as a fresh instance and go on.
            Err(WorkerError::Api { status: 409, .. }) if campaign.fleet => {
                started.fetch_sub(claimed, Ordering::Relaxed);
                incarnation += 1;
                client.abandon_worker();
                client.register_worker(
                    &format!("{}-x{incarnation}", node.label()),
                    node.site.name,
                    "sim-gpu",
                )?;
                continue;
            }
            Err(e) => return Err(attribute(e, node, &client)),
        };
        for trial in trials {
            if trial.requeued {
                report.requeued_taken += 1;
            }

            // The simulated training converges to the objective value at
            // the suggested point: bad hyperparameters → high asymptote,
            // which is what gives the pruner something to act on, and
            // keeps final values in objective units (comparable to f*).
            let value = campaign.objective.eval_params(&trial.params);
            let curve = LearningCurve {
                asymptote: value,
                start: value + 3.0 * (1.0 + rng.f64()),
                rate: 0.05 + 0.1 * rng.f64(),
                noise: 0.02,
            };

            // Does this trial get preempted partway? (opportunistic
            // resources)
            let preempt_at = if rng.chance(node.site.preempt) {
                Some(1 + rng.below(campaign.steps_per_trial.max(1)))
            } else {
                None
            };

            let mut pruned = false;
            let mut preempted = false;
            let mut stolen = false;
            for step in 1..=campaign.steps_per_trial {
                if let Some(p) = preempt_at {
                    if step >= p {
                        // Node vanishes mid-trial: no fail report, exactly
                        // like a killed spot instance. The server's reaper
                        // handles it (or, in fleet mode, lease expiry
                        // requeues the trial).
                        preempted = true;
                        break;
                    }
                }
                work_delay(campaign, node, &mut rng);
                report.steps_executed += 1;
                let loss = curve.at(step, &mut rng);
                net_delay(node, &mut rng);
                match client.should_prune(&trial, step, loss) {
                    Ok(true) => {
                        pruned = true;
                        break;
                    }
                    Ok(false) => {}
                    // Fleet mode: our lease expired mid-trial and the
                    // trial was re-homed — it is not ours to report on
                    // anymore.
                    Err(WorkerError::Api { status: 409, .. }) if campaign.fleet => {
                        stolen = true;
                        break;
                    }
                    Err(e) => return Err(attribute(e, node, &client)),
                }
                if campaign.fleet {
                    // Renew the worker lease alongside the progress report.
                    let _ = client.heartbeat();
                }
            }

            if stolen {
                // Nothing to record: the trial's new holder reports it.
            } else if preempted {
                report.preempted += 1;
                if campaign.fleet {
                    // The instance is gone: no fail report, no deregister,
                    // no further heartbeats — exactly like a killed spot
                    // node. The server's lease expiry requeues the trial.
                    // The thread then plays the *replacement* instance,
                    // registering as a fresh worker.
                    client.abandon_worker();
                    incarnation += 1;
                    client.register_worker(
                        &format!("{}-r{incarnation}", node.label()),
                        node.site.name,
                        "sim-gpu",
                    )?;
                }
            } else if pruned {
                report.pruned += 1;
            } else {
                // Final objective: the converged value (+ observation
                // noise — the "noisy loss function" setting of the
                // paper's §1).
                let final_loss = curve.final_loss() + rng.normal() * 0.005;
                net_delay(node, &mut rng);
                match client.tell(&trial, final_loss) {
                    Ok(_) => {
                        report.completed += 1;
                        site_completed += 1;
                        report.best = Some(match report.best {
                            None => final_loss,
                            Some(b) => b.min(final_loss),
                        });
                    }
                    // Fleet mode: a straggler tell after our lease expired
                    // and the re-homed trial finished elsewhere.
                    Err(WorkerError::Api { status: 409, .. }) if campaign.fleet => {}
                    Err(e) => return Err(attribute(e, node, &client)),
                }
            }
        }
    }
    if campaign.fleet {
        let _ = client.deregister_worker();
    }
    report.by_site.push((node.site.name.to_string(), site_completed));
    Ok(report)
}

/// One dashboard reader: walks the paginated studies list, pages every
/// study's trials to exhaustion through cursors, reads the incumbent,
/// and long-polls the event feed from its last seen watermark. Returns
/// the number of pages read. Every request goes through the
/// materialized-view read path — a viewer never takes a shard lock, so
/// any K of these run without perturbing ask/tell latency.
fn viewer_loop(server: SocketAddr, _viewer_id: usize, stop: &AtomicBool) -> u64 {
    use std::collections::HashMap;
    let Ok(mut client) = crate::http::Client::connect(server) else {
        return 0;
    };
    client.set_timeout(Duration::from_secs(10));
    let mut pages = 0u64;
    let mut watermarks: HashMap<u64, u64> = HashMap::new();
    while !stop.load(Ordering::Relaxed) {
        let Ok(resp) = client.get("/api/studies?limit=32") else {
            break;
        };
        let Ok(list) = resp.json_body() else { break };
        let Some(studies) = list.get("studies").as_arr() else {
            break;
        };
        pages += 1;
        for s in studies {
            let Some(sid) = s.get("id").as_u64() else { continue };
            let mut path = format!("/api/studies/{sid}/trials?limit=64");
            loop {
                let Ok(r) = client.get(&path) else { return pages };
                let Ok(page) = r.json_body() else { return pages };
                pages += 1;
                match page.get("next_cursor").as_str() {
                    Some(c) => {
                        path = format!("/api/studies/{sid}/trials?limit=64&cursor={c}");
                    }
                    None => break,
                }
            }
            if client.get(&format!("/api/studies/{sid}/best")).is_err() {
                return pages;
            }
            pages += 1;
            // Short poll window: the viewer notices campaign shutdown
            // within ~50ms instead of parking for the full server cap.
            let since = watermarks.get(&sid).copied().unwrap_or(0);
            let Ok(r) =
                client.get(&format!("/api/studies/{sid}/events?since={since}&timeout=0.05"))
            else {
                return pages;
            };
            if let Ok(ev) = r.json_body() {
                if let Some(w) = ev.get("watermark").as_u64() {
                    watermarks.insert(sid, w);
                }
            }
            pages += 1;
            if stop.load(Ordering::Relaxed) {
                break;
            }
        }
    }
    pages
}

fn net_delay(node: &NodeProfile, rng: &mut Rng) {
    if node.site.net_latency_us == 0 {
        return;
    }
    let jitter = 0.5 + rng.f64();
    std::thread::sleep(Duration::from_micros(
        (node.site.net_latency_us as f64 * jitter) as u64,
    ));
}

fn work_delay(campaign: &Campaign, node: &NodeProfile, rng: &mut Rng) {
    if campaign.step_cost_us == 0 {
        return;
    }
    let us = campaign.step_cost_us as f64 / node.site.speed * (0.8 + 0.4 * rng.f64());
    std::thread::sleep(Duration::from_micros(us as u64));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{HopaasConfig, HopaasServer};

    fn server() -> HopaasServer {
        HopaasServer::start(
            "127.0.0.1:0",
            HopaasConfig { auth_required: false, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn small_campaign_completes() {
        let s = server();
        let mut c = Campaign::new(s.addr(), "t".into(), Objective::Sphere);
        c.n_nodes = 6;
        c.max_trials = 30;
        c.steps_per_trial = 5;
        c.step_cost_us = 50;
        let report = c.run().unwrap();
        let total = report.completed + report.pruned + report.preempted;
        assert!(total >= 25, "most started trials resolve: {report:?}");
        assert!(report.best.is_some());
        assert!(report.steps_executed > 0);
        // All 4 site kinds participated (6 nodes over 4 sites).
        assert!(report.by_site.len() >= 3, "{:?}", report.by_site);
        s.stop();
    }

    #[test]
    fn small_campaign_with_batched_asks() {
        // Nodes fetch 4 trials per round trip; the campaign still
        // resolves every started trial and respects max_trials.
        let s = server();
        let mut c = Campaign::new(s.addr(), "t".into(), Objective::Sphere);
        c.n_nodes = 3;
        c.max_trials = 24;
        c.steps_per_trial = 4;
        c.step_cost_us = 50;
        c.ask_batch = 4;
        c.pruner = None;
        // Reliable sites only: every fetched trial runs to completion.
        let sites = [Site { name: "cloud", speed: 1.0, preempt: 0.0, net_latency_us: 50 }];
        let report = c.run_with_sites(&sites).unwrap();
        assert!(report.completed >= 24, "{report:?}");
        assert!(report.best.is_some());
        // Nothing left running server-side: every batched trial was told.
        for sv in s.engine.studies_json().as_arr().unwrap() {
            assert_eq!(sv.get("n_running").as_i64(), Some(0), "{sv}");
        }
        s.stop();
    }

    #[test]
    fn campaign_with_viewers_reads_pages_while_fleet_writes() {
        // Dashboard readers run for the whole campaign: they page the
        // studies list, walk every study's trial cursors, read /best and
        // long-poll /events — all against live writers — and must never
        // break the fleet (errors surface as an early-returning viewer
        // with a low page count, and as node_loop failures).
        let s = server();
        let mut c = Campaign::new(s.addr(), "t".into(), Objective::Sphere);
        c.n_nodes = 4;
        c.max_trials = 20;
        c.steps_per_trial = 3;
        c.step_cost_us = 100;
        c.viewers = 3;
        let report = c.run().unwrap();
        assert!(report.viewer_pages > 0, "viewers read nothing: {report:?}");
        assert!(report.completed + report.pruned + report.preempted > 0);
        s.stop();
    }

    #[test]
    fn campaign_errors_carry_node_and_request_id() {
        // A campaign against an authenticated server with a bad token
        // dies on its first ask; the surfaced error names the failing
        // node and carries the trace id of the rejected request, which
        // is recoverable from the coordinator's trace buffer.
        let s = HopaasServer::start(
            "127.0.0.1:0",
            HopaasConfig { auth_required: true, ..Default::default() },
        )
        .unwrap();
        let mut c = Campaign::new(s.addr(), "bogus".into(), Objective::Sphere);
        c.n_nodes = 1;
        c.max_trials = 2;
        match c.run() {
            Err(WorkerError::Api { status: 401, detail, request_id }) => {
                assert!(detail.contains("(node marconi100-00)"), "{detail}");
                let rid = request_id.expect("trace id attached to the error");
                assert!(
                    s.engine.tracer().get(&rid).is_some(),
                    "trace {rid} not recoverable"
                );
            }
            other => panic!("expected attributed 401, got {other:?}"),
        }
        s.stop();
    }

    #[test]
    fn campaign_report_merge() {
        let mut a = CampaignReport {
            completed: 2,
            pruned: 1,
            preempted: 0,
            requeued_taken: 0,
            steps_executed: 10,
            best: Some(1.0),
            wall: Duration::ZERO,
            by_site: vec![("x".into(), 2)],
            viewer_pages: 0,
        };
        let b = CampaignReport {
            completed: 3,
            pruned: 0,
            preempted: 1,
            requeued_taken: 2,
            steps_executed: 20,
            best: Some(0.5),
            wall: Duration::ZERO,
            by_site: vec![("x".into(), 1), ("y".into(), 2)],
            viewer_pages: 0,
        };
        a.merge(&b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.requeued_taken, 2);
        assert_eq!(a.best, Some(0.5));
        assert_eq!(a.by_site, vec![("x".to_string(), 3), ("y".to_string(), 2)]);
    }

    #[test]
    fn fleet_campaign_requeues_preempted_trials() {
        // Fleet protocol: preempted nodes vanish mid-trial without a
        // goodbye; a short lease timeout plus an expiry pump re-homes
        // their trials onto surviving workers — no reap_stale involved.
        let config = HopaasConfig {
            auth_required: false,
            engine: crate::coordinator::engine::EngineConfig {
                lease_timeout: Some(0.05),
                // A trial may be preempted repeatedly (its new worker
                // can die too); keep the budget above any plausible
                // chain so the preempted == re-assigned ledger balances.
                requeue_max: 1000,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = HopaasServer::start("127.0.0.1:0", config).unwrap();
        let engine = s.engine.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let pump = {
            let stop = stop.clone();
            let engine = engine.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    engine.expire_leases();
                    std::thread::sleep(Duration::from_millis(10));
                }
            })
        };
        let mut c = Campaign::new(s.addr(), "t".into(), Objective::Sphere);
        c.fleet = true;
        c.n_nodes = 4;
        c.max_trials = 30;
        c.steps_per_trial = 4;
        c.step_cost_us = 100;
        c.pruner = None;
        // Every node on one high-preemption site.
        let sites = [Site { name: "spot", speed: 1.0, preempt: 0.4, net_latency_us: 50 }];
        let report = c.run_with_sites(&sites).unwrap();
        // Give the pump time to expire the last abandoned leases, then
        // drain the requeue queue with a fresh worker.
        std::thread::sleep(Duration::from_millis(120));
        engine.expire_leases();
        let mut drained = 0u64;
        {
            let mut client = HopaasClient::connect(s.addr(), "t".into()).unwrap();
            client.register_worker("drain", "spot", "sim").unwrap();
            let spec = StudySpec::new(&c.study_name)
                .properties_json(c.objective.properties())
                .sampler(c.sampler);
            loop {
                // Keep the drain worker's own lease alive while the
                // pump is still expiring in the background.
                let _ = client.heartbeat();
                let t = client.ask(&spec).unwrap();
                if !t.requeued {
                    // A fresh trial — finish it and stop draining.
                    client.tell(&t, 1.0).unwrap();
                    break;
                }
                if client.tell(&t, 1.0).is_ok() {
                    drained += 1;
                }
            }
        }
        stop.store(true, Ordering::Relaxed);
        pump.join().unwrap();
        assert!(report.preempted > 0, "preemption never triggered: {report:?}");
        // Every preempted trial was either re-assigned during the
        // campaign or drained above — none left queued, none failed by
        // a reaper (reap_stale was never called), none still running.
        // (`>=` because a heartbeat gap on a loaded machine can expire
        // a live worker too — that requeue has no preempt event.)
        let stats = engine.stats_json();
        let fleet = stats.get("fleet");
        assert_eq!(fleet.get("requeue_depth").as_u64(), Some(0), "{stats}");
        assert!(
            report.requeued_taken + drained >= report.preempted,
            "preempted trials unaccounted for: {report:?} drained={drained}"
        );
        for sv in engine.studies_json().as_arr().unwrap() {
            assert_eq!(sv.get("n_running").as_i64(), Some(0), "{sv}");
            assert_eq!(sv.get("n_failed").as_i64(), Some(0), "{sv}");
        }
        s.stop();
    }

    #[test]
    fn multi_tenant_campaign_completes_and_drains_tenant_slots() {
        // Two tenants share four fleet nodes under a 1-lease tenant
        // quota: denials surface as 429s the node loop already backs
        // off on, the campaign still completes, and every tenant slot
        // is returned by the time the fleet drains.
        let config = HopaasConfig {
            auth_required: false,
            engine: crate::coordinator::engine::EngineConfig {
                tenant_quota: 1,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = HopaasServer::start("127.0.0.1:0", config).unwrap();
        let mut c = Campaign::new(s.addr(), "t".into(), Objective::Sphere);
        c.fleet = true;
        c.n_nodes = 4;
        c.max_trials = 16;
        c.steps_per_trial = 3;
        c.step_cost_us = 50;
        c.pruner = None;
        c.tenants = vec!["alice".into(), "bob".into()];
        // Reliable site: no preemption, so no expiry pump is needed.
        let sites = [Site { name: "cloud", speed: 1.0, preempt: 0.0, net_latency_us: 50 }];
        let report = c.run_with_sites(&sites).unwrap();
        assert!(report.completed > 0, "{report:?}");
        let fl = s.engine.fleet().lock();
        assert_eq!(fl.sched.tenant_active_total(), 0, "all tenant slots returned");
        assert_eq!(fl.leases.len(), 0);
        drop(fl);
        s.stop();
    }

    #[test]
    fn preempted_trials_are_reaped_not_lost() {
        // High preemption site: the server should still converge because
        // preempted (silent) trials get reaped, not counted as completed.
        let config = HopaasConfig {
            auth_required: false,
            engine: crate::coordinator::engine::EngineConfig {
                reap_after: Some(0.05),
                ..Default::default()
            },
            ..Default::default()
        };
        let s = HopaasServer::start("127.0.0.1:0", config).unwrap();
        let mut c = Campaign::new(s.addr(), "t".into(), Objective::Sphere);
        c.n_nodes = 4;
        c.max_trials = 20;
        c.steps_per_trial = 4;
        c.step_cost_us = 100;
        c.seed = 3;
        let report = c.run().unwrap();
        std::thread::sleep(Duration::from_millis(80));
        let reaped = s.engine.reap_stale();
        // All preempted trials are eventually reaped.
        assert!(reaped as u64 <= report.preempted + 1);
        s.stop();
    }
}

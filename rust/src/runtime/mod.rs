//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! This is the only place the `xla` crate is touched. The compile path
//! (`make artifacts`) leaves HLO **text** plus a `manifest.json` in
//! `artifacts/`; at startup the runtime creates one PJRT CPU client,
//! compiles each referenced module once, and caches the executables.
//! Python never runs on this path.

mod manifest;

pub use manifest::{Manifest, Variant};

use crate::sync::MutexExt;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Runtime errors.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("artifacts not found at {0} — run `make artifacts`")]
    ArtifactsMissing(PathBuf),
    #[error("manifest: {0}")]
    Manifest(String),
    #[error("xla: {0}")]
    Xla(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// A compiled-executable cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// The PJRT client handle is internally synchronized; executions are
// thread-safe per PJRT semantics (the C API allows concurrent Execute).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.exists() {
            return Err(RuntimeError::ArtifactsMissing(dir));
        }
        let manifest = Manifest::load(&manifest_path)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifact location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HOPAAS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Platform string (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (cached) an HLO text file in the artifact dir.
    pub fn load(
        &self,
        file: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, RuntimeError> {
        {
            let cache = self.cache.lock_safe();
            if let Some(exe) = cache.get(file) {
                return Ok(exe.clone());
            }
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| RuntimeError::Manifest("bad path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock_safe()
            .insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a compiled module on literal inputs; unpacks the
    /// `return_tuple=True` convention into a flat vector.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>, RuntimeError> {
        let result = exe.execute::<xla::Literal>(inputs)?;
        let literal = result[0][0].to_literal_sync()?;
        Ok(literal.to_tuple()?)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock_safe().len()
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal, RuntimeError> {
    debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::from(data[0]));
    }
    let flat = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Read an f32 literal back into a Vec.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>, RuntimeError> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a scalar f32.
pub fn literal_scalar(lit: &xla::Literal) -> Result<f32, RuntimeError> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Runtime::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn literal_roundtrip() {
        let lit = literal_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(literal_to_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let s = literal_f32(&[], &[2.5]).unwrap();
        assert_eq!(literal_scalar(&s).unwrap(), 2.5);
    }

    #[test]
    fn open_missing_dir_fails_cleanly() {
        match Runtime::open("/definitely/not/here") {
            Err(RuntimeError::ArtifactsMissing(_)) => {}
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("should not open"),
        }
    }

    #[test]
    fn loads_and_caches_eval_artifact() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        let v = rt.manifest.variants[0].clone();
        let e1 = rt.load(&v.eval_file).unwrap();
        let e2 = rt.load(&v.eval_file).unwrap();
        assert!(std::sync::Arc::ptr_eq(&e1, &e2), "second load hits cache");
        assert_eq!(rt.cached(), 1);
    }

    #[test]
    fn eval_artifact_executes() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        let v = rt.manifest.variants[0].clone();
        let exe = rt.load(&v.eval_file).unwrap();
        // Zero generator + zero noise → W1 against real data is finite.
        let mut inputs = Vec::new();
        for shape in &v.eval_inputs {
            let n: usize = shape.iter().product::<usize>().max(1);
            inputs.push(literal_f32(shape, &vec![0.1; n]).unwrap());
        }
        let out = rt.execute(&exe, &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let w1 = literal_scalar(&out[0]).unwrap();
        assert!(w1.is_finite() && w1 >= 0.0, "w1={w1}");
    }
}

//! `artifacts/manifest.json` — the contract between aot.py and the Rust
//! runtime: per-variant file names, the positional input signature, and
//! the flat-state layout.

use super::RuntimeError;
use crate::json::Value;
use std::path::Path;

/// One compiled (width, depth) architecture variant.
#[derive(Clone, Debug)]
pub struct Variant {
    pub width: u64,
    pub depth: u64,
    pub train_file: String,
    pub eval_file: String,
    /// Shapes of the trainable arrays (params only, in order).
    pub param_shapes: Vec<Vec<usize>>,
    /// Leading arrays of the param block belonging to the generator.
    pub n_gen_arrays: usize,
    /// Full train-state length (params + m + v + t).
    pub n_state: usize,
    /// Positional input shapes of the train artifact.
    pub train_inputs: Vec<Vec<usize>>,
    /// Positional input shapes of the eval artifact.
    pub eval_inputs: Vec<Vec<usize>>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub cond_dim: usize,
    pub feat_dim: usize,
    pub latent_dim: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub variants: Vec<Variant>,
}

fn shapes(v: &Value) -> Result<Vec<Vec<usize>>, RuntimeError> {
    v.as_arr()
        .ok_or_else(|| RuntimeError::Manifest("expected shape array".into()))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| RuntimeError::Manifest("expected shape".into()))?
                .iter()
                .map(|d| {
                    d.as_u64()
                        .map(|x| x as usize)
                        .ok_or_else(|| RuntimeError::Manifest("bad dim".into()))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load and validate.
    pub fn load(path: &Path) -> Result<Manifest, RuntimeError> {
        let text = std::fs::read_to_string(path)?;
        let v = crate::json::parse(&text)
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let dim = |k: &str| -> Result<usize, RuntimeError> {
            v.get(k)
                .as_u64()
                .map(|x| x as usize)
                .ok_or_else(|| RuntimeError::Manifest(format!("missing '{k}'")))
        };
        let mut variants = Vec::new();
        for vv in v.get("variants").as_arr().unwrap_or(&[]) {
            let variant = Variant {
                width: vv.get("width").as_u64().unwrap_or(0),
                depth: vv.get("depth").as_u64().unwrap_or(0),
                train_file: vv
                    .get("train_file")
                    .as_str()
                    .ok_or_else(|| RuntimeError::Manifest("missing train_file".into()))?
                    .to_string(),
                eval_file: vv
                    .get("eval_file")
                    .as_str()
                    .ok_or_else(|| RuntimeError::Manifest("missing eval_file".into()))?
                    .to_string(),
                param_shapes: shapes(vv.get("param_shapes"))?,
                n_gen_arrays: vv.get("n_gen_arrays").as_u64().unwrap_or(0) as usize,
                n_state: vv.get("n_state").as_u64().unwrap_or(0) as usize,
                train_inputs: shapes(vv.get("train_inputs"))?,
                eval_inputs: shapes(vv.get("eval_inputs"))?,
            };
            // Internal consistency: state = 3·params + 1.
            if variant.n_state != 3 * variant.param_shapes.len() + 1 {
                return Err(RuntimeError::Manifest(format!(
                    "variant {}x{}: n_state {} != 3·{}+1",
                    variant.width,
                    variant.depth,
                    variant.n_state,
                    variant.param_shapes.len()
                )));
            }
            variants.push(variant);
        }
        if variants.is_empty() {
            return Err(RuntimeError::Manifest("no variants".into()));
        }
        Ok(Manifest {
            cond_dim: dim("cond_dim")?,
            feat_dim: dim("feat_dim")?,
            latent_dim: dim("latent_dim")?,
            batch: dim("batch")?,
            eval_batch: dim("eval_batch")?,
            variants,
        })
    }

    /// Find a variant by (width, depth).
    pub fn variant(&self, width: u64, depth: u64) -> Option<&Variant> {
        self.variants
            .iter()
            .find(|v| v.width == width && v.depth == depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    const SAMPLE: &str = r#"{
        "cond_dim": 3, "feat_dim": 4, "latent_dim": 8,
        "batch": 256, "eval_batch": 512,
        "variants": [{
            "width": 32, "depth": 2,
            "train_file": "t.hlo.txt", "eval_file": "e.hlo.txt",
            "param_shapes": [[11,32],[32],[32,32],[32],[32,4],[4],
                             [7,32],[32],[32,32],[32],[32,1],[1]],
            "n_gen_arrays": 6, "n_state": 37,
            "train_inputs": [[11,32]],
            "eval_inputs": [[11,32]]
        }]
    }"#;

    #[test]
    fn loads_sample() {
        let d = TempDir::new("manifest");
        let p = d.path().join("manifest.json");
        std::fs::write(&p, SAMPLE).unwrap();
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.variants.len(), 1);
        let v = m.variant(32, 2).unwrap();
        assert_eq!(v.param_shapes.len(), 12);
        assert_eq!(v.param_shapes[1], vec![32]);
        assert!(m.variant(64, 2).is_none());
    }

    #[test]
    fn rejects_inconsistent_state() {
        let d = TempDir::new("manifest-bad");
        let p = d.path().join("manifest.json");
        std::fs::write(&p, SAMPLE.replace("\"n_state\": 37", "\"n_state\": 12")).unwrap();
        assert!(Manifest::load(&p).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let d = TempDir::new("manifest-miss");
        let p = d.path().join("manifest.json");
        std::fs::write(&p, r#"{"cond_dim": 3}"#).unwrap();
        assert!(Manifest::load(&p).is_err());
    }
}

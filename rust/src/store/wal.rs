//! crc32-framed append-only write-ahead log.
//!
//! Frame format (little-endian):
//! ```text
//! [u32 len][u32 crc32(payload)][payload: len bytes of JSON utf-8]
//! ```
//! A torn tail (partial frame or checksum mismatch) is truncated on
//! replay; everything before it is recovered.

use crate::json::Value;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;

/// Maximum single-record size — a guard against a corrupt length prefix
/// making replay allocate gigabytes.
const MAX_RECORD: u32 = 64 * 1024 * 1024;

/// WAL error type.
#[derive(Debug, thiserror::Error)]
pub enum WalError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("corrupt: {0}")]
    Corrupt(String),
}

/// Counters for metrics and compaction policy.
#[derive(Clone, Copy, Debug, Default)]
pub struct WalStats {
    pub records: u64,
    pub bytes: u64,
    /// Torn/corrupt bytes discarded at the last replay.
    pub truncated_bytes: u64,
    /// Torn-tail incidents detected at replay (0 or 1 per log file —
    /// replay stops at the first invalid frame, so anything past it is
    /// unparseable and counts as one truncation, not per-record).
    pub truncations: u64,
}

/// Append-only log handle.
pub struct Wal {
    path: PathBuf,
    file: File,
    stats: WalStats,
}

impl Wal {
    /// Open or create the log at `path`.
    pub fn open(path: PathBuf) -> Result<Wal, WalError> {
        let file = OpenOptions::new()
            .create(true)
            .read(true)
            .append(true)
            .open(&path)?;
        let bytes = file.metadata()?.len();
        Ok(Wal {
            path,
            file,
            stats: WalStats { records: 0, bytes, truncated_bytes: 0, truncations: 0 },
        })
    }

    /// Append one JSON record; fsync before returning so an acknowledged
    /// API mutation is durable.
    pub fn append(&mut self, value: &Value) -> Result<(), WalError> {
        self.append_nosync(value)?;
        self.sync()
    }

    /// Append one JSON record *without* flushing. The record is durable
    /// only after a subsequent [`Wal::sync`]. Group commit uses this to
    /// frame a whole batch of records and pay for one fsync.
    pub fn append_nosync(&mut self, value: &Value) -> Result<(), WalError> {
        let payload = value.to_string().into_bytes();
        let len = payload.len() as u32;
        if len > MAX_RECORD {
            return Err(WalError::Corrupt("record too large".into()));
        }
        let crc = crc32fast::hash(&payload);
        let mut frame = Vec::with_capacity(payload.len() + 8);
        frame.extend_from_slice(&len.to_le_bytes());
        frame.extend_from_slice(&crc.to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        self.stats.records += 1;
        self.stats.bytes += frame.len() as u64;
        Ok(())
    }

    /// Flush everything appended so far to stable storage.
    pub fn sync(&mut self) -> Result<(), WalError> {
        self.file.sync_data()?;
        Ok(())
    }

    /// Roll the log back to a previously captured [`Wal::stats`] mark,
    /// discarding frames appended (but not yet acknowledged) since.
    /// Group commit uses this when a batch write fails, so a NACKed
    /// mutation can never be resurrected by a later fsync + replay.
    pub fn truncate_to(&mut self, mark: WalStats) -> Result<(), WalError> {
        self.file.set_len(mark.bytes)?;
        self.file.sync_data()?;
        self.file.seek(SeekFrom::End(0))?;
        self.stats.bytes = mark.bytes;
        self.stats.records = mark.records;
        Ok(())
    }

    /// Replay all valid records from the start; truncates a torn tail.
    pub fn replay(&mut self) -> Result<Vec<Value>, WalError> {
        let mut buf = Vec::new();
        self.file.seek(SeekFrom::Start(0))?;
        self.file.read_to_end(&mut buf)?;

        let mut records = Vec::new();
        let mut off = 0usize;
        let mut valid_end = 0usize;
        while off + 8 <= buf.len() {
            let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
            let crc = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap());
            if len > MAX_RECORD {
                break; // corrupt length: stop at last valid frame
            }
            let start = off + 8;
            let end = start + len as usize;
            if end > buf.len() {
                break; // torn tail
            }
            let payload = &buf[start..end];
            if crc32fast::hash(payload) != crc {
                break; // bit rot / torn write
            }
            let text = match std::str::from_utf8(payload) {
                Ok(t) => t,
                Err(_) => break,
            };
            let value = match crate::json::parse(text) {
                Ok(v) => v,
                Err(_) => break,
            };
            records.push(value);
            off = end;
            valid_end = end;
        }

        if valid_end < buf.len() {
            // Discard the invalid tail so future appends start clean.
            self.stats.truncated_bytes = (buf.len() - valid_end) as u64;
            self.stats.truncations += 1;
            self.file.set_len(valid_end as u64)?;
            self.file.sync_data()?;
        }
        self.file.seek(SeekFrom::End(0))?;
        self.stats.records = records.len() as u64;
        self.stats.bytes = valid_end as u64;
        Ok(records)
    }

    /// Truncate the log (after a snapshot has been durably written).
    pub fn reset(&mut self) -> Result<(), WalError> {
        self.file.set_len(0)?;
        self.file.sync_data()?;
        self.file.seek(SeekFrom::Start(0))?;
        self.stats = WalStats::default();
        Ok(())
    }

    pub fn stats(&self) -> WalStats {
        self.stats
    }

    /// Path of the log file.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, TempDir};

    fn val(i: i64) -> Value {
        let mut o = Value::obj();
        o.set("i", i);
        Value::Obj(o)
    }

    #[test]
    fn append_replay_roundtrip() {
        let d = TempDir::new("wal-rt");
        let mut w = Wal::open(d.path().join("w.log")).unwrap();
        for i in 0..20 {
            w.append(&val(i)).unwrap();
        }
        let rec = w.replay().unwrap();
        assert_eq!(rec.len(), 20);
        assert_eq!(rec[7], val(7));
    }

    #[test]
    fn reopen_preserves_records() {
        let d = TempDir::new("wal-reopen");
        let p = d.path().join("w.log");
        {
            let mut w = Wal::open(p.clone()).unwrap();
            w.append(&val(1)).unwrap();
            w.append(&val(2)).unwrap();
        }
        let mut w = Wal::open(p).unwrap();
        assert_eq!(w.replay().unwrap().len(), 2);
        // Appending after replay continues the log.
        w.append(&val(3)).unwrap();
        assert_eq!(w.replay().unwrap().len(), 3);
    }

    #[test]
    fn torn_tail_truncated() {
        let d = TempDir::new("wal-torn");
        let p = d.path().join("w.log");
        {
            let mut w = Wal::open(p.clone()).unwrap();
            w.append(&val(1)).unwrap();
            w.append(&val(2)).unwrap();
        }
        // Simulate a crash mid-write: append garbage half-frame.
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[42u8, 0, 0]).unwrap();
        }
        let mut w = Wal::open(p.clone()).unwrap();
        let rec = w.replay().unwrap();
        assert_eq!(rec.len(), 2);
        assert!(w.stats().truncated_bytes > 0);
        assert_eq!(w.stats().truncations, 1, "one torn-tail incident counted");
        // Log is clean again: append works and replays fully.
        w.append(&val(3)).unwrap();
        assert_eq!(w.replay().unwrap().len(), 3);
    }

    #[test]
    fn corrupt_crc_stops_replay() {
        let d = TempDir::new("wal-crc");
        let p = d.path().join("w.log");
        {
            let mut w = Wal::open(p.clone()).unwrap();
            for i in 0..3 {
                w.append(&val(i)).unwrap();
            }
        }
        // Flip a byte in the middle record's payload.
        let mut bytes = std::fs::read(&p).unwrap();
        let frame0 = 8 + u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        bytes[frame0 + 10] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();

        let mut w = Wal::open(p).unwrap();
        let rec = w.replay().unwrap();
        assert_eq!(rec.len(), 1, "replay stops at last valid record");
    }

    #[test]
    fn nosync_batch_then_sync_replays_all() {
        let d = TempDir::new("wal-batch");
        let mut w = Wal::open(d.path().join("w.log")).unwrap();
        for i in 0..5 {
            w.append_nosync(&val(i)).unwrap();
        }
        w.sync().unwrap();
        let rec = w.replay().unwrap();
        assert_eq!(rec.len(), 5);
        assert_eq!(rec[4], val(4));
    }

    #[test]
    fn reset_empties() {
        let d = TempDir::new("wal-reset");
        let mut w = Wal::open(d.path().join("w.log")).unwrap();
        w.append(&val(1)).unwrap();
        w.reset().unwrap();
        assert!(w.replay().unwrap().is_empty());
        w.append(&val(2)).unwrap();
        assert_eq!(w.replay().unwrap(), vec![val(2)]);
    }

    #[test]
    fn prop_recovery_is_prefix() {
        // Property: for any sequence of appended records and any byte
        // truncation point, replay yields a prefix of the appended
        // sequence.
        prop::check(40, |g| {
            let d = TempDir::new("wal-prop");
            let p = d.path().join("w.log");
            let n = g.usize(1, 12);
            let vals: Vec<Value> = (0..n as i64).map(val).collect();
            {
                let mut w = Wal::open(p.clone()).unwrap();
                for v in &vals {
                    w.append(v).unwrap();
                }
            }
            let full = std::fs::read(&p).unwrap();
            let cut = g.usize(0, full.len());
            std::fs::write(&p, &full[..cut]).unwrap();
            let mut w = Wal::open(p).unwrap();
            let rec = w.replay().unwrap();
            prop::assert_holds(
                rec.len() <= vals.len() && rec[..] == vals[..rec.len()],
                format!("not a prefix: {} of {} (cut {cut})", rec.len(), vals.len()),
            )
        });
    }
}

//! Group-commit WAL writer.
//!
//! The seed engine fsync'd once per mutation while holding the global
//! engine lock, so a 1000-tell burst paid 1000 serialized disk flushes.
//! [`GroupWal`] moves all file I/O onto one dedicated writer thread fed
//! by a bounded channel:
//!
//! 1. engine shards enqueue a [`Record`] plus a completion handle and
//!    block until the handle fires;
//! 2. the writer drains whatever has accumulated (up to
//!    [`GroupWalConfig::batch_max`]), appends every frame unsynced in
//!    arrival order, stamps each record with a global commit `seq`,
//!    issues **one** fsync for the whole batch, then acknowledges every
//!    sender.
//!
//! A mutation is therefore acknowledged only after its record is on
//! stable storage — the crash contract `fault_tolerance.rs` tests is
//! unchanged — but N shards committing concurrently share a flush
//! instead of queueing N of them.
//!
//! Compaction is driven by the engine in three phases
//! ([`GroupWal::begin_compact`] / per-shard cut specs /
//! [`GroupWal::finish_compact`]): rotate the log to a new epoch, cut
//! one snapshot segment per shard, commit the manifest and GC sealed
//! logs. The writer thread no longer performs the segment I/O itself —
//! it only answers [`GroupWal::shard_cut`] (the shard's exact
//! `next_seq` high-water mark) and [`GroupWal::reuse_segment`]
//! roundtrips, both cheap map reads, while the actual
//! write→fsync→rename of each segment runs on the engine's compaction
//! pool through [`SegmentWriter`] handles. Because the engine holds a
//! shard's lock across both its appends and its `shard_cut` roundtrip,
//! the cut is exact: a segment covers precisely the records the writer
//! stamped for that shard before the cut command arrived. Commit acks
//! keep flowing between those roundtrips, so a compaction of N shards
//! no longer stalls the commit path for the sum of all segment I/O —
//! only [`GroupWal::finish_compact`] (manifest rename + GC, the single
//! serialization point of the crash-consistency contract) still runs
//! on the writer.

use super::{Record, SegmentWriter, Storage};
use crate::json::Value;
use crate::obs::{self, ReqId};
use crate::sync::MutexExt;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Tuning for the writer thread.
#[derive(Clone, Copy, Debug)]
pub struct GroupWalConfig {
    /// Soft cap on records flushed under one fsync: the drain loop
    /// stops admitting further jobs once a batch reaches this size.
    /// It can be exceeded by one job's worth of records — a job
    /// (notably a bulk [`GroupWal::append_many`]) is committed and
    /// acknowledged atomically, never split across fsyncs. With
    /// `adaptive` set this is the *ceiling* the live limit grows toward.
    pub batch_max: usize,
    /// Bound on queued-but-unwritten jobs (backpressure).
    pub queue_depth: usize,
    /// Adapt the live batch limit to the observed queue depth: each
    /// commit that fills the current limit doubles it (up to
    /// `batch_max`), each commit at a quarter of it or less halves it
    /// (down to `batch_min`). Under a burst the limit climbs within a
    /// few batches so thousands of mutations share single-digit fsyncs;
    /// when the burst passes it decays back, keeping the tail-latency
    /// cost of a huge half-empty drain window low. Off = the fixed
    /// `batch_max` behavior (the `--wal-batch N` override).
    pub adaptive: bool,
    /// Floor of the adaptive limit.
    pub batch_min: usize,
}

impl Default for GroupWalConfig {
    fn default() -> Self {
        GroupWalConfig { batch_max: 256, queue_depth: 1024, adaptive: false, batch_min: 16 }
    }
}

/// Commit statistics, shared with the engine for `/metrics`. Only
/// *successful* (durable, acknowledged) batches count here; a failed
/// batch is rolled back and recorded in `failed_batches` instead.
#[derive(Debug, Default)]
pub struct GroupWalStats {
    /// Batches flushed durably (successful fsync count).
    pub batches: AtomicU64,
    /// Records committed through the writer.
    pub records: AtomicU64,
    /// Size of the most recent committed batch.
    pub last_batch: AtomicU64,
    /// Largest committed batch observed.
    pub max_batch: AtomicU64,
    /// Batches that failed (write or fsync error) and were rolled back.
    pub failed_batches: AtomicU64,
    /// Live batch limit of the adaptive group-commit (equals the fixed
    /// `batch_max` when adaptation is off).
    pub batch_limit: AtomicU64,
    /// Segment cuts skipped by compaction because the shard had no new
    /// records since its previous segment (clean-shard reuse).
    pub segments_reused: AtomicU64,
}

impl GroupWalStats {
    /// `(batches, records, last_batch, max_batch)` snapshot.
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.batches.load(Ordering::Relaxed),
            self.records.load(Ordering::Relaxed),
            self.last_batch.load(Ordering::Relaxed),
            self.max_batch.load(Ordering::Relaxed),
        )
    }
}

/// Per-request commit attribution, returned with every durable append
/// ack: how long the job waited in the writer queue, the duration of
/// the *shared* fsync its batch issued, and the batch size. The engine
/// turns these into `wal_queue`/`wal_fsync` trace stages, so a slow ask
/// shows whether it paid queue wait or flush time — and how many other
/// requests amortized that flush.
#[derive(Clone, Copy, Debug, Default)]
pub struct WalAckInfo {
    /// Microseconds between enqueue and the batch starting to commit.
    pub queue_us: u64,
    /// Microseconds of the batch's single fsync.
    pub fsync_us: u64,
    /// Records committed (and acknowledged) by the batch.
    pub batch_len: u64,
}

/// One committed batch in the writer's bounded attribution ledger: its
/// seq range, fsync duration, and **which trace ids it acknowledged** —
/// the per-request side of "who shared this flush", surfaced under
/// `wal_commit.recent_batches` in `/api/stats`.
#[derive(Clone, Debug)]
pub struct BatchTrace {
    pub seq_first: u64,
    pub seq_last: u64,
    pub records: u64,
    pub fsync_us: u64,
    pub traces: Vec<String>,
}

/// Committed batches kept in the attribution ledger.
const LEDGER_CAP: usize = 64;

/// Result of a follower's [`ReplicationSource::fetch`].
#[derive(Clone, Debug)]
pub enum ReplFetch {
    /// Acknowledged records with `seq >= from`, in commit order.
    /// `next` is the cursor to resume from (one past the last record
    /// returned); `primary_next` is the seq the primary will stamp on
    /// its next commit — the follower's lag is `primary_next - next`.
    Batches { records: Vec<Record>, next: u64, primary_next: u64 },
    /// `from` fell below the buffer's retention floor: the records were
    /// evicted, and the follower must re-bootstrap from a snapshot
    /// bundle before resuming at `oldest` or later.
    TooOld { oldest: u64 },
    /// Nothing acknowledged past `from` yet; long-poll and retry.
    UpToDate { next: u64 },
}

/// Buffered acknowledged batches: the bounded in-memory window of the
/// replication log a follower can tail without touching the primary's
/// disk.
struct ReplBuf {
    /// `(seq_first, seq_last, records)` per committed batch, oldest
    /// first. Seqs inside the window need not be contiguous — records
    /// already covered by a snapshot segment never enter it — but every
    /// *uncovered* acknowledged record with `seq >= floor` is present.
    batches: VecDeque<(u64, u64, Vec<Record>)>,
    /// Total records across `batches` (the eviction unit).
    records: usize,
    /// Oldest seq still fetchable; fetches below it get
    /// [`ReplFetch::TooOld`].
    floor: u64,
    /// Seq the next committed record will be stamped with.
    next_seq: u64,
}

/// The primary side of WAL shipping, extracted from the group-commit
/// writer: every batch is published here *after* its fsync and *before*
/// its senders are acknowledged, so "acknowledged ⇒ durable **and**
/// shipped to the replication buffer" — promoting a caught-up follower
/// can therefore never lose an acknowledged mutation. The buffer is
/// bounded by record count; followers that fall behind the window
/// re-bootstrap from a snapshot bundle (`TooOld`).
pub struct ReplicationSource {
    inner: Mutex<ReplBuf>,
    /// Shared long-poll waker (the HTTP pump's [`crate::http::Notify`],
    /// also fired by view publication). Parked `/api/repl/log` polls
    /// re-check the buffer whenever it fires.
    signal: Arc<crate::http::Notify>,
    /// Record-count cap of the buffer.
    cap: usize,
}

impl ReplicationSource {
    /// New source retaining up to `cap` records. `floor`/`next_seq`
    /// describe the log position at startup; `tail` seeds the buffer
    /// with the uncovered records recovery just replayed, so a follower
    /// bootstrapping from the snapshot bundle (which only covers up to
    /// the segment cuts) can fetch the remainder without raw log
    /// access. `signal` is the pump waker shared with view publication.
    pub fn new(
        cap: usize,
        floor: u64,
        next_seq: u64,
        tail: Vec<Record>,
        signal: Arc<crate::http::Notify>,
    ) -> ReplicationSource {
        let cap = cap.max(1);
        let mut buf = ReplBuf { batches: VecDeque::new(), records: 0, floor, next_seq };
        if let (Some(first), Some(last)) = (tail.first(), tail.last()) {
            let (seq_first, seq_last) = (first.seq, last.seq);
            buf.records = tail.len();
            buf.batches.push_back((seq_first, seq_last, tail));
            buf.next_seq = buf.next_seq.max(seq_last + 1);
        }
        let src = ReplicationSource { inner: Mutex::new(buf), signal, cap };
        src.evict_locked(&mut src.inner.lock_safe());
        src
    }

    /// Drop whole batches from the front until the record cap holds,
    /// raising the retention floor past everything evicted.
    fn evict_locked(&self, g: &mut ReplBuf) {
        while g.records > self.cap && g.batches.len() > 1 {
            if let Some((_, last, recs)) = g.batches.pop_front() {
                g.records -= recs.len();
                g.floor = g.floor.max(last + 1);
            }
        }
        // A single oversized batch still has to be evictable, or the
        // buffer would exceed its cap forever.
        if g.records > self.cap {
            if let Some((_, last, recs)) = g.batches.pop_front() {
                g.records -= recs.len();
                g.floor = g.floor.max(last + 1);
            }
        }
    }

    /// Publish one acknowledged (durably fsynced) batch. Called by the
    /// WAL writer thread between fsync and ack.
    pub fn publish(&self, records: Vec<Record>) {
        let (Some(first), Some(last)) = (records.first(), records.last()) else { return };
        let (seq_first, seq_last) = (first.seq, last.seq);
        let mut g = self.inner.lock_safe();
        g.records += records.len();
        g.batches.push_back((seq_first, seq_last, records));
        g.next_seq = g.next_seq.max(seq_last + 1);
        self.evict_locked(&mut g);
    }

    /// All buffered records with `seq >= from`, capped at `max`.
    pub fn fetch(&self, from: u64, max: usize) -> ReplFetch {
        let g = self.inner.lock_safe();
        if from < g.floor {
            return ReplFetch::TooOld { oldest: g.floor };
        }
        let mut out: Vec<Record> = Vec::new();
        'batches: for (_, seq_last, recs) in &g.batches {
            if *seq_last < from {
                continue;
            }
            for r in recs {
                if r.seq >= from {
                    out.push(r.clone());
                    if out.len() >= max.max(1) {
                        break 'batches;
                    }
                }
            }
        }
        match out.last() {
            None => ReplFetch::UpToDate { next: g.next_seq.max(from) },
            Some(last) => ReplFetch::Batches {
                next: last.seq + 1,
                primary_next: g.next_seq,
                records: out,
            },
        }
    }

    /// Seq the next committed record will carry (the follower's target).
    pub fn next_seq(&self) -> u64 {
        self.inner.lock_safe().next_seq
    }

    /// Oldest fetchable seq (diagnostics / `/api/stats`).
    pub fn floor(&self) -> u64 {
        self.inner.lock_safe().floor
    }

    /// Buffered record count (diagnostics / `/api/stats`).
    pub fn buffered(&self) -> usize {
        self.inner.lock_safe().records
    }

    /// Wake parked followers; fired by the writer after each publish.
    pub fn notify(&self) {
        self.signal.notify_all();
    }

    /// The shared waker, for callers that park on buffer changes.
    pub fn signal(&self) -> Arc<crate::http::Notify> {
        self.signal.clone()
    }
}

type Ack = SyncSender<Result<WalAckInfo, String>>;
type CountAck = SyncSender<Result<u64, String>>;

/// An append in flight: records, the requesting trace (if the calling
/// thread is handling a traced request), enqueue time, completion.
struct AppendJob {
    records: Vec<Record>,
    trace: Option<ReqId>,
    enqueued: Instant,
    ack: Ack,
}

enum Cmd {
    /// One or more records committed (and acknowledged) together.
    Append(AppendJob),
    /// Compaction phase 1: rotate the log to a new epoch.
    BeginCompact(Ack),
    /// Compaction phase 2 (spec): report the shard's exact cut — the
    /// seq after its last stamped record in the active epoch (0 when it
    /// has none). The engine holds that shard's lock across the
    /// roundtrip; the segment itself is cut on a pool thread.
    ShardCut(u32, SyncSender<Result<u64, String>>),
    /// Compaction phase 2, clean-shard fast path: the shard's previous
    /// manifest entry (file + cut), to be carried into the new manifest
    /// without rewriting the segment. Replies `None` when no previous
    /// segment is known — the engine then cuts in full.
    ReuseSegment(u32, SyncSender<Result<Option<(String, u64)>, String>>),
    /// Compaction phase 3: commit the given segment set with a manifest
    /// rename, GC sealed logs. Replies with the record count carried
    /// over in the active log.
    FinishCompact(Vec<(u32, String, u64)>, u64, u64, CountAck),
}

/// Handle to the writer thread. Cloneable-by-`Arc` at the engine level;
/// dropping the last handle shuts the writer down after draining.
pub struct GroupWal {
    tx: Option<SyncSender<Cmd>>,
    stats: Arc<GroupWalStats>,
    /// Bounded ledger of recent commit batches with the trace ids each
    /// one acknowledged (written by the writer thread, read at
    /// `/api/stats` time).
    ledger: Arc<Mutex<VecDeque<BatchTrace>>>,
    /// Segment-cutting handle over the writer's storage, cloned out to
    /// compaction-pool threads (shares the fault hook + killed flag).
    cutter: SegmentWriter,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl GroupWal {
    /// Take ownership of `storage` and start the writer thread.
    /// `next_seq` continues the commit sequence recovered from replay;
    /// `prev_segments` seeds the clean-shard reuse table with the
    /// segments of the manifest the recovery just loaded (empty when
    /// the layout changed or no manifest existed — every shard is then
    /// cut in full at the first compaction). `repl`, when given, has
    /// every committed batch published to it between fsync and ack —
    /// the primary side of WAL shipping.
    pub fn start(
        storage: Storage,
        config: GroupWalConfig,
        next_seq: u64,
        prev_segments: HashMap<u32, (String, u64)>,
        repl: Option<Arc<ReplicationSource>>,
    ) -> GroupWal {
        let (tx, rx) = std::sync::mpsc::sync_channel(config.queue_depth.max(1));
        let stats = Arc::new(GroupWalStats::default());
        let thread_stats = stats.clone();
        let ledger = Arc::new(Mutex::new(VecDeque::with_capacity(LEDGER_CAP)));
        let thread_ledger = ledger.clone();
        let cutter = storage.segment_writer();
        let handle = std::thread::Builder::new()
            .name("hopaas-wal".into())
            .spawn(move || {
                Writer::new(
                    storage,
                    config,
                    next_seq,
                    prev_segments,
                    thread_stats,
                    thread_ledger,
                    repl,
                )
                .run(rx)
            })
            .expect("spawn wal writer");
        GroupWal { tx: Some(tx), stats, ledger, cutter, handle: Some(handle) }
    }

    /// Durably append one record: blocks until the record's batch has
    /// been fsynced. Errors if the write or flush failed — the caller
    /// must not acknowledge the mutation in that case. Returns the
    /// batch attribution ([`WalAckInfo`]) for the request's trace.
    pub fn append(&self, record: Record) -> Result<WalAckInfo, String> {
        self.append_many(vec![record])
    }

    /// Durably append several records in one roundtrip: all of them
    /// share (at most) one fsync and one channel wait. Used by bulk
    /// paths like reaping, where per-record roundtrips would serialize
    /// K fsync latencies under a shard lock.
    pub fn append_many(&self, records: Vec<Record>) -> Result<WalAckInfo, String> {
        if records.is_empty() {
            return Ok(WalAckInfo::default());
        }
        // The calling thread holds the request's span (if any): tag the
        // job so the commit batch can record which traces it acks.
        let trace = obs::current_id();
        let enqueued = Instant::now();
        self.roundtrip(|ack| Cmd::Append(AppendJob { records, trace, enqueued, ack }))
    }

    /// Compaction phase 1: rotate the log to a fresh epoch. No shard
    /// lock is required — appends racing with the rotation land on one
    /// side of it or the other, and both sides replay correctly.
    pub fn begin_compact(&self) -> Result<(), String> {
        self.roundtrip(Cmd::BeginCompact).map(|_| ())
    }

    /// Compaction phase 2 (spec): the shard's exact segment cut — the
    /// seq one past its last record stamped into the active epoch (0
    /// when it has none). The caller must hold that shard's lock (and
    /// only that one) across the roundtrip so no record of the shard is
    /// in flight; the segment covering `[.., cut)` is then written on a
    /// pool thread via [`GroupWal::segment_writer`], with the lock
    /// already released — records committed after the cut simply replay
    /// on top of the segment at recovery.
    pub fn shard_cut(&self, shard: u32) -> Result<u64, String> {
        let tx = self.tx.as_ref().expect("wal writer running");
        let (ack_tx, ack_rx) = std::sync::mpsc::sync_channel(1);
        tx.send(Cmd::ShardCut(shard, ack_tx))
            .map_err(|_| "wal writer stopped".to_string())?;
        ack_rx.recv().map_err(|_| "wal writer stopped".to_string())?
    }

    /// Compaction phase 2, clean-shard fast path: the shard's previous
    /// manifest entry `(file, cut)`, to reference in the upcoming
    /// manifest instead of cutting a new segment. Only valid when the
    /// shard has appended **no** records since that segment was cut
    /// (the engine's per-shard dirty counter proves this; the caller
    /// holds the shard's lock). Returns `None` when the writer has no
    /// previous segment for the shard — the caller must then cut in
    /// full.
    pub fn reuse_segment(&self, shard: u32) -> Result<Option<(String, u64)>, String> {
        let tx = self.tx.as_ref().expect("wal writer running");
        let (ack_tx, ack_rx) = std::sync::mpsc::sync_channel(1);
        tx.send(Cmd::ReuseSegment(shard, ack_tx))
            .map_err(|_| "wal writer stopped".to_string())?;
        ack_rx.recv().map_err(|_| "wal writer stopped".to_string())?
    }

    /// The handle pool threads use to cut segments concurrently. Shares
    /// the storage's fault hook and killed flag, so a kill-point firing
    /// mid-cut also fails the writer thread — one simulated power cut.
    pub fn segment_writer(&self) -> SegmentWriter {
        self.cutter.clone()
    }

    /// Compaction phase 3: commit `segments` (every entry durably
    /// renamed into place, in any order) with the manifest rename, then
    /// GC sealed logs. Returns the number of records carried over in
    /// the active log (the engine's new `wal_records` counter value).
    pub fn finish_compact(
        &self,
        segments: Vec<(u32, String, u64)>,
        next_trial_id: u64,
        next_study_id: u64,
    ) -> Result<u64, String> {
        let tx = self.tx.as_ref().expect("wal writer running");
        let (ack_tx, ack_rx) = std::sync::mpsc::sync_channel(1);
        tx.send(Cmd::FinishCompact(segments, next_trial_id, next_study_id, ack_tx))
            .map_err(|_| "wal writer stopped".to_string())?;
        ack_rx.recv().map_err(|_| "wal writer stopped".to_string())?
    }

    /// Commit statistics for metrics export.
    pub fn stats(&self) -> &GroupWalStats {
        &self.stats
    }

    /// The recent-batch attribution ledger as JSON (newest last): seq
    /// range, fsync duration, and the trace ids each batch acked.
    pub fn ledger_json(&self) -> Value {
        let g = self.ledger.lock_safe();
        Value::Arr(
            g.iter()
                .map(|b| {
                    let mut o = Value::obj();
                    o.set("seq_first", b.seq_first)
                        .set("seq_last", b.seq_last)
                        .set("records", b.records)
                        .set("fsync_us", b.fsync_us)
                        .set(
                            "traces",
                            b.traces.iter().map(String::as_str).collect::<Vec<_>>(),
                        );
                    Value::Obj(o)
                })
                .collect(),
        )
    }

    fn roundtrip(&self, make: impl FnOnce(Ack) -> Cmd) -> Result<WalAckInfo, String> {
        let tx = self.tx.as_ref().expect("wal writer running");
        let (ack_tx, ack_rx) = std::sync::mpsc::sync_channel(1);
        tx.send(make(ack_tx))
            .map_err(|_| "wal writer stopped".to_string())?;
        ack_rx
            .recv()
            .map_err(|_| "wal writer stopped".to_string())?
    }
}

impl Drop for GroupWal {
    fn drop(&mut self) {
        // Closing the channel lets the writer drain the queue and exit;
        // joining guarantees every acknowledged record hit the disk
        // before the storage directory can be reopened.
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Writer-thread state.
struct Writer {
    storage: Storage,
    config: GroupWalConfig,
    /// Live batch limit: fixed at `config.batch_max` unless adaptive.
    limit: usize,
    /// Next global commit seq to stamp.
    next_seq: u64,
    /// Per-shard cut positions (`last stamped seq + 1`) for records in
    /// the *current epoch's* log. Cleared on rotation: sealed logs are
    /// covered wholesale by the manifest epoch, so only post-rotation
    /// records need a per-shard cut.
    shard_next: HashMap<u32, u64>,
    /// Segments of the last committed manifest, by shard — the
    /// clean-shard reuse table.
    prev_segments: HashMap<u32, (String, u64)>,
    stats: Arc<GroupWalStats>,
    ledger: Arc<Mutex<VecDeque<BatchTrace>>>,
    /// Replication buffer committed batches are published to (primary
    /// role; `None` on standalone nodes).
    repl: Option<Arc<ReplicationSource>>,
}

impl Writer {
    fn new(
        storage: Storage,
        config: GroupWalConfig,
        next_seq: u64,
        prev_segments: HashMap<u32, (String, u64)>,
        stats: Arc<GroupWalStats>,
        ledger: Arc<Mutex<VecDeque<BatchTrace>>>,
        repl: Option<Arc<ReplicationSource>>,
    ) -> Writer {
        let config = GroupWalConfig {
            batch_max: config.batch_max.max(1),
            batch_min: config.batch_min.clamp(1, config.batch_max.max(1)),
            ..config
        };
        let limit = if config.adaptive { config.batch_min } else { config.batch_max };
        stats.batch_limit.store(limit as u64, Ordering::Relaxed);
        Writer {
            storage,
            config,
            limit,
            next_seq,
            shard_next: HashMap::new(),
            prev_segments,
            stats,
            ledger,
            repl,
        }
    }

    fn run(mut self, rx: Receiver<Cmd>) {
        let mut pending: Option<Cmd> = None;
        loop {
            let cmd = match pending.take() {
                Some(c) => c,
                None => match rx.recv() {
                    Ok(c) => c,
                    Err(_) => break,
                },
            };
            match cmd {
                Cmd::Append(job) => pending = self.commit_batch(job, &rx),
                Cmd::BeginCompact(ack) => {
                    let result = self
                        .storage
                        .begin_compact()
                        .map(|()| WalAckInfo::default())
                        .map_err(|e| e.to_string());
                    if result.is_ok() {
                        self.shard_next.clear();
                    }
                    let _ = ack.send(result);
                }
                Cmd::ShardCut(shard, ack) => {
                    // A cheap map read — commit acks between cut specs
                    // keep flowing while pool threads do the segment
                    // I/O this thread used to serialize.
                    let cut = self.shard_next.get(&shard).copied().unwrap_or(0);
                    let _ = ack.send(Ok(cut));
                }
                Cmd::ReuseSegment(shard, ack) => {
                    let entry = self.prev_segments.get(&shard).map(|(file, cut)| {
                        self.stats.segments_reused.fetch_add(1, Ordering::Relaxed);
                        (file.clone(), *cut)
                    });
                    let _ = ack.send(Ok(entry));
                }
                Cmd::FinishCompact(segments, next_trial_id, next_study_id, ack) => {
                    let result = match self.storage.finish_compact(
                        &segments,
                        self.next_seq,
                        next_trial_id,
                        next_study_id,
                    ) {
                        Ok(()) => {
                            self.prev_segments = segments
                                .iter()
                                .map(|(shard, file, cut)| (*shard, (file.clone(), *cut)))
                                .collect();
                            Ok(self.storage.wal_stats().records)
                        }
                        Err(e) => Err(e.to_string()),
                    };
                    let _ = ack.send(result);
                }
            }
        }
    }

    /// Commit one append batch (greedily drained from the queue) under
    /// a single fsync. Returns a deferred non-append command if the
    /// drain hit one.
    fn commit_batch(&mut self, job: AppendJob, rx: &Receiver<Cmd>) -> Option<Cmd> {
        let mut total = job.records.len();
        let mut jobs: Vec<AppendJob> = vec![job];
        // Greedy drain: everything already queued joins this commit,
        // which is what collapses per-mutation fsyncs under load while
        // adding zero latency when idle.
        let mut deferred = None;
        while total < self.limit {
            match rx.try_recv() {
                Ok(Cmd::Append(j)) => {
                    total += j.records.len();
                    jobs.push(j);
                }
                Ok(other) => {
                    deferred = Some(other);
                    break;
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        // Every job enqueued before this instant: the gap to it is the
        // per-job queue wait reported in its ack.
        let batch_start = Instant::now();

        let mark = self.storage.wal_stats();
        let seq_mark = self.next_seq;
        let shard_mark = self.shard_next.clone();
        let mut result: Result<(), String> = Ok(());
        for job in jobs.iter_mut() {
            for rec in job.records.iter_mut() {
                rec.seq = self.next_seq;
                self.next_seq += 1;
                self.shard_next.insert(rec.shard, rec.seq + 1);
                if result.is_ok() {
                    if let Err(e) = self.storage.append_nosync(rec) {
                        result = Err(e.to_string());
                    }
                }
            }
        }
        let mut fsync_us = 0u64;
        if result.is_ok() {
            let t0 = Instant::now();
            if let Err(e) = self.storage.sync() {
                result = Err(e.to_string());
            }
            fsync_us = t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
        }
        if result.is_err() {
            // Every job in this batch is NACKed, so none of its frames
            // may survive: a later successful fsync would otherwise make
            // a rejected mutation durable and replay would resurrect
            // state the engine never acknowledged. Roll the file — and
            // the seq counters — back to the batch start (best effort;
            // a failing truncate is reported alongside the original
            // error).
            self.next_seq = seq_mark;
            self.shard_next = shard_mark;
            if let Err(e) = self.storage.rollback(mark) {
                result = result.map_err(|orig| format!("{orig}; rollback failed: {e}"));
            }
        }

        // Ship the durable batch to the replication buffer *before*
        // acknowledging any sender, so "acknowledged ⇒ shipped" holds
        // and promoting a caught-up follower can never lose an acked
        // mutation. The `repl.publish` kill-point models a crash after
        // fsync but before the publish: the batch is durable on disk
        // (no rollback — a real power cut cannot un-fsync), NACKed, and
        // never shipped; recovery replays it, followers never saw it.
        // `repl.ack` crashes after the publish, before the acks: the
        // batch is durable *and* shipped but unacknowledged.
        if result.is_ok() {
            if let Some(src) = &self.repl {
                if let Err(e) = self.storage.fault_point("repl.publish") {
                    result = Err(e.to_string());
                } else {
                    src.publish(
                        jobs.iter().flat_map(|j| j.records.iter().cloned()).collect(),
                    );
                    if let Err(e) = self.storage.fault_point("repl.ack") {
                        result = Err(e.to_string());
                    }
                }
            }
        }

        match &result {
            Ok(()) => {
                let n = total as u64;
                self.stats.batches.fetch_add(1, Ordering::Relaxed);
                self.stats.records.fetch_add(n, Ordering::Relaxed);
                self.stats.last_batch.store(n, Ordering::Relaxed);
                self.stats.max_batch.fetch_max(n, Ordering::Relaxed);
                // Adapt the limit to the observed queue depth: a full
                // drain means the queue outran the window (grow), a
                // near-empty one means the burst passed (shrink).
                if self.config.adaptive {
                    if total >= self.limit {
                        self.limit = (self.limit * 2).min(self.config.batch_max);
                    } else if total * 4 <= self.limit {
                        self.limit = (self.limit / 2).max(self.config.batch_min);
                    }
                    self.stats.batch_limit.store(self.limit as u64, Ordering::Relaxed);
                }
                // Record the batch — seq range, fsync cost, and the
                // trace ids it acknowledged — in the bounded ledger.
                let traces: Vec<String> =
                    jobs.iter().filter_map(|j| j.trace.map(|t| t.as_str().to_string())).collect();
                let mut g = self.ledger.lock_safe();
                if g.len() == LEDGER_CAP {
                    g.pop_front();
                }
                g.push_back(BatchTrace {
                    seq_first: seq_mark,
                    seq_last: self.next_seq.saturating_sub(1),
                    records: n,
                    fsync_us,
                    traces,
                });
            }
            Err(_) => {
                self.stats.failed_batches.fetch_add(1, Ordering::Relaxed);
            }
        }
        for job in jobs {
            let queue_us = batch_start
                .saturating_duration_since(job.enqueued)
                .as_micros()
                .min(u64::MAX as u128) as u64;
            let info = WalAckInfo { queue_us, fsync_us, batch_len: total as u64 };
            let _ = job.ack.send(result.clone().map(|()| info));
        }
        // Wake parked follower polls last: the `repl.wake` kill-point
        // crashes after the acks — the batch is durable, shipped and
        // acknowledged, so nothing may be lost; followers merely find
        // it at their next deadline poll instead of instantly.
        if result.is_ok() {
            if let Some(src) = &self.repl {
                if self.storage.fault_point("repl.wake").is_ok() {
                    src.notify();
                }
            }
        }
        deferred
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;
    use crate::testutil::TempDir;

    fn rec(i: i64) -> Record {
        let mut o = Value::obj();
        o.set("i", i);
        Record::new("e", Value::Obj(o))
    }

    fn reload(dir: &std::path::Path) -> Vec<Record> {
        let mut s = Storage::open(dir).unwrap();
        s.load().unwrap().events
    }

    #[test]
    fn appends_are_durable_when_acknowledged() {
        let d = TempDir::new("group-ack");
        {
            let storage = Storage::open(d.path()).unwrap();
            let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
            for i in 0..10 {
                w.append(rec(i)).unwrap();
            }
            // Dropping joins the writer; but every append above was
            // already acknowledged, hence already fsynced.
        }
        let events = reload(d.path());
        assert_eq!(events.len(), 10);
        assert_eq!(events[4], rec(4));
    }

    #[test]
    fn seq_is_stamped_in_commit_order() {
        let d = TempDir::new("group-seq");
        {
            let storage = Storage::open(d.path()).unwrap();
            let w = GroupWal::start(storage, GroupWalConfig::default(), 7, HashMap::new(), None);
            for i in 0..5 {
                w.append(rec(i)).unwrap();
            }
        }
        let events = reload(d.path());
        let seqs: Vec<u64> = events.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![7, 8, 9, 10, 11]);
    }

    #[test]
    fn concurrent_appends_share_fsyncs() {
        let d = TempDir::new("group-batch");
        let n_threads = 8;
        let per_thread = 25;
        let stats;
        {
            let storage = Storage::open(d.path()).unwrap();
            let w =
                Arc::new(GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None));
            let handles: Vec<_> = (0..n_threads)
                .map(|t| {
                    let w = w.clone();
                    std::thread::spawn(move || {
                        for i in 0..per_thread {
                            w.append(rec((t * 1000 + i) as i64)).unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            stats = w.stats().snapshot();
        }
        let total = (n_threads * per_thread) as u64;
        let (batches, records, _, max_batch) = stats;
        assert_eq!(records, total);
        assert!(batches <= total, "batches ({batches}) never exceed records");
        assert!(max_batch >= 1);
        // Every record survived, exactly once, whatever the batching.
        let events = reload(d.path());
        assert_eq!(events.len(), total as usize);
        let mut seqs: Vec<u64> = events.iter().map(|r| r.seq).collect();
        let sorted = {
            let mut s = seqs.clone();
            s.sort_unstable();
            s
        };
        assert_eq!(seqs, sorted, "file order == commit order");
        seqs.dedup();
        assert_eq!(seqs.len(), total as usize, "seq unique");
    }

    #[test]
    fn append_many_is_one_roundtrip_for_all_records() {
        let d = TempDir::new("group-many");
        {
            let storage = Storage::open(d.path()).unwrap();
            let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
            w.append_many((0..50).map(rec).collect()).unwrap();
            w.append_many(Vec::new()).unwrap(); // no-op, no batch
            let (batches, records, last, _) = w.stats().snapshot();
            assert_eq!(batches, 1, "bulk append shares one flush");
            assert_eq!(records, 50);
            assert_eq!(last, 50);
        }
        let events = reload(d.path());
        assert_eq!(events.len(), 50);
        assert_eq!(events[49], rec(49));
    }

    #[test]
    fn failed_batch_leaves_no_phantom_frames() {
        let d = TempDir::new("group-rollback");
        {
            let storage = Storage::open(d.path()).unwrap();
            let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
            w.append(rec(1)).unwrap();
            // A record above MAX_RECORD fails its append mid-batch; the
            // good record sharing the batch is NACKed and must not
            // survive on disk either — a later fsync would otherwise
            // make a rejected mutation durable.
            let huge = Record::new("e", Value::Str("x".repeat(65 * 1024 * 1024)));
            assert!(w.append_many(vec![rec(2), huge]).is_err());
            // Writer stays usable; seq continues from the rollback point.
            w.append(rec(3)).unwrap();
            // Only the two durable batches count as committed.
            let (batches, records, _, _) = w.stats().snapshot();
            assert_eq!(batches, 2);
            assert_eq!(records, 2);
            assert_eq!(w.stats().failed_batches.load(Ordering::Relaxed), 1);
        }
        let events = reload(d.path());
        assert_eq!(events, vec![rec(1), rec(3)]);
        assert_eq!(events[1].seq, 1, "seq rolled back with the frames");
    }

    #[test]
    fn append_ack_attributes_batch_and_ledger_records_traces() {
        let d = TempDir::new("group-ledger");
        let storage = Storage::open(d.path()).unwrap();
        let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
        // Tag the calling thread with a span: the append must carry the
        // request's trace id into the commit batch's ledger entry.
        let tracer = obs::Tracer::new(obs::TracerConfig::default());
        let span = tracer.begin(Some("trace-append-1"), obs::OpKind::Ask);
        obs::install(span);
        let info = w.append(rec(1)).unwrap();
        let span = obs::take().unwrap();
        tracer.finish(span, 200);
        assert_eq!(info.batch_len, 1);
        // Untraced appends land in the ledger with no trace ids.
        let info2 = w.append_many(vec![rec(2), rec(3)]).unwrap();
        assert_eq!(info2.batch_len, 2);
        let ledger = w.ledger_json();
        let arr = ledger.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("traces").at(0).as_str(), Some("trace-append-1"));
        assert_eq!(arr[0].get("seq_first").as_u64(), Some(0));
        assert_eq!(arr[0].get("seq_last").as_u64(), Some(0));
        assert_eq!(arr[1].get("records").as_u64(), Some(2));
        assert_eq!(arr[1].get("seq_last").as_u64(), Some(2));
        assert!(arr[1].get("traces").as_arr().unwrap().is_empty());
        // The ledger is bounded: it keeps the most recent batches only.
        for i in 0..(LEDGER_CAP as i64 + 10) {
            w.append(rec(100 + i)).unwrap();
        }
        let arr_len = w.ledger_json().as_arr().unwrap().len();
        assert_eq!(arr_len, LEDGER_CAP);
    }

    #[test]
    fn adaptive_batch_limit_grows_and_decays() {
        let d = TempDir::new("group-adaptive");
        {
            let storage = Storage::open(d.path()).unwrap();
            let config = GroupWalConfig {
                batch_max: 64,
                batch_min: 4,
                adaptive: true,
                ..Default::default()
            };
            let w = GroupWal::start(storage, config, 0, HashMap::new(), None);
            assert_eq!(w.stats().batch_limit.load(Ordering::Relaxed), 4);
            // A commit that fills the live limit doubles it.
            w.append_many((0..64).map(rec).collect()).unwrap();
            assert_eq!(w.stats().batch_limit.load(Ordering::Relaxed), 8);
            w.append_many((0..64).map(rec).collect()).unwrap();
            assert_eq!(w.stats().batch_limit.load(Ordering::Relaxed), 16);
            // Idle single appends decay it back to the floor.
            for i in 0..20 {
                w.append(rec(i)).unwrap();
            }
            assert_eq!(w.stats().batch_limit.load(Ordering::Relaxed), 4);
        }
        // Fixed mode pins the limit at batch_max.
        let storage = Storage::open(d.path()).unwrap();
        let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
        w.append(rec(1)).unwrap();
        assert_eq!(w.stats().batch_limit.load(Ordering::Relaxed), 256);
    }

    /// Cut one segment for `shard` the way the engine's compaction pool
    /// does: cut spec from the writer, segment I/O through a
    /// [`SegmentWriter`] handle.
    fn cut(w: &GroupWal, shard: u32, snap: Value) -> (u32, String, u64) {
        let cut = w.shard_cut(shard).unwrap();
        let file = w.segment_writer().write_segment(shard, cut, &snap).unwrap();
        (shard, file, cut)
    }

    #[test]
    fn reuse_segment_carries_previous_manifest_entry() {
        let d = TempDir::new("group-reuse");
        {
            let storage = Storage::open(d.path()).unwrap();
            let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
            w.append(rec(0)).unwrap();
            assert!(w.reuse_segment(0).unwrap().is_none(), "no previous manifest yet");
            w.begin_compact().unwrap();
            let mut snap = Value::obj();
            snap.set("gen", 1);
            let seg = cut(&w, 0, Value::Obj(snap));
            w.finish_compact(vec![seg], 1, 1).unwrap();
            // The second compaction reuses shard 0's segment as-is.
            w.begin_compact().unwrap();
            let (file, prev_cut) = w.reuse_segment(0).unwrap().expect("previous entry");
            w.finish_compact(vec![(0, file, prev_cut)], 1, 1).unwrap();
            assert_eq!(w.stats().segments_reused.load(Ordering::Relaxed), 1);
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert_eq!(loaded.segments.len(), 1);
        assert_eq!(loaded.segments[0].get("studies").get("gen").as_i64(), Some(1));
        assert!(loaded.events.is_empty());
    }

    #[test]
    fn incremental_compact_covers_and_carries() {
        let d = TempDir::new("group-compact");
        {
            let storage = Storage::open(d.path()).unwrap();
            let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
            for i in 0..6 {
                w.append(rec(i)).unwrap();
            }
            w.begin_compact().unwrap();
            let mut snap = Value::obj();
            snap.set("count", 6);
            let seg = cut(&w, 0, Value::Obj(snap));
            let carried = w.finish_compact(vec![seg], 7, 2).unwrap();
            assert_eq!(carried, 0, "no records appended since rotation");
            w.append(rec(100)).unwrap();
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        let m = loaded.manifest.unwrap();
        assert_eq!(m.get("version").as_u64(), Some(super::super::FORMAT_VERSION));
        assert_eq!(m.get("next_trial_id").as_u64(), Some(7));
        assert_eq!(loaded.segments.len(), 1);
        assert_eq!(
            loaded.segments[0].get("studies").get("count").as_i64(),
            Some(6)
        );
        assert_eq!(loaded.events, vec![rec(100)]);
    }

    fn source(cap: usize, floor: u64, next: u64, tail: Vec<Record>) -> Arc<ReplicationSource> {
        Arc::new(ReplicationSource::new(
            cap,
            floor,
            next,
            tail,
            Arc::new(crate::http::Notify::new()),
        ))
    }

    #[test]
    fn replication_source_fetch_evicts_and_seeds() {
        let src = source(4, 0, 0, Vec::new());
        match src.fetch(0, 100) {
            ReplFetch::UpToDate { next } => assert_eq!(next, 0),
            other => panic!("expected UpToDate, got {other:?}"),
        }
        let batch = |seqs: &[u64]| {
            src.publish(
                seqs.iter()
                    .map(|&s| {
                        let mut r = rec(s as i64);
                        r.seq = s;
                        r
                    })
                    .collect(),
            )
        };
        batch(&[0, 1]);
        batch(&[2, 3]);
        match src.fetch(1, 100) {
            ReplFetch::Batches { records, next, primary_next } => {
                assert_eq!(records.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![1, 2, 3]);
                assert_eq!(next, 4);
                assert_eq!(primary_next, 4);
            }
            other => panic!("expected Batches, got {other:?}"),
        }
        // `max` caps the page; the cursor resumes mid-window.
        match src.fetch(0, 2) {
            ReplFetch::Batches { records, next, .. } => {
                assert_eq!(records.len(), 2);
                assert_eq!(next, 2);
            }
            other => panic!("expected Batches, got {other:?}"),
        }
        // A third batch overflows the 4-record cap: the oldest batch is
        // evicted and the floor rises past it.
        batch(&[4, 5]);
        assert_eq!(src.floor(), 2);
        assert_eq!(src.buffered(), 4);
        match src.fetch(0, 100) {
            ReplFetch::TooOld { oldest } => assert_eq!(oldest, 2),
            other => panic!("expected TooOld, got {other:?}"),
        }
        // A recovered tail seeds the window (gaps allowed: covered
        // records never enter it).
        let tail: Vec<Record> = [3u64, 7, 9]
            .iter()
            .map(|&s| {
                let mut r = rec(s as i64);
                r.seq = s;
                r
            })
            .collect();
        let seeded = source(100, 2, 10, tail);
        match seeded.fetch(4, 100) {
            ReplFetch::Batches { records, next, primary_next } => {
                assert_eq!(records.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![7, 9]);
                assert_eq!(next, 10);
                assert_eq!(primary_next, 10);
            }
            other => panic!("expected Batches, got {other:?}"),
        }
    }

    #[test]
    fn acknowledged_batches_are_shipped_before_ack() {
        let d = TempDir::new("group-repl-ship");
        let storage = Storage::open(d.path()).unwrap();
        let src = source(1024, 0, 0, Vec::new());
        let w = GroupWal::start(
            storage,
            GroupWalConfig::default(),
            0,
            HashMap::new(),
            Some(src.clone()),
        );
        w.append(rec(1)).unwrap();
        w.append_many(vec![rec(2), rec(3)]).unwrap();
        // Every acknowledged record is already in the buffer.
        match src.fetch(0, 100) {
            ReplFetch::Batches { records, next, .. } => {
                assert_eq!(records.len(), 3);
                assert_eq!(next, 3);
            }
            other => panic!("expected Batches, got {other:?}"),
        }
    }

    #[test]
    fn repl_publish_killpoint_nacks_durable_unshipped_batch() {
        // A crash between fsync and publish: the batch is durable on
        // disk (recovery replays it) but NACKed and never shipped.
        let d = TempDir::new("group-repl-kill");
        let hook: super::super::FaultHook =
            Arc::new(|point: &str| point == "repl.publish");
        let storage = Storage::open_with_hook(d.path(), Some(hook)).unwrap();
        let src = source(1024, 0, 0, Vec::new());
        let w = GroupWal::start(
            storage,
            GroupWalConfig::default(),
            0,
            HashMap::new(),
            Some(src.clone()),
        );
        assert!(w.append(rec(1)).is_err(), "publish kill-point NACKs the batch");
        match src.fetch(0, 100) {
            ReplFetch::UpToDate { .. } => {}
            other => panic!("record must not have shipped, got {other:?}"),
        }
        drop(w);
        // ...but it *is* durable: a real power cut cannot un-fsync.
        let events = reload(d.path());
        assert_eq!(events, vec![rec(1)]);
    }

    #[test]
    fn compact_cut_splits_around_segment() {
        // Records committed after rotation but before the shard's cut
        // are covered by the segment; records after the cut replay —
        // including records committed while the segment file itself is
        // being written (the cut spec, not the file write, is the
        // coverage boundary).
        let d = TempDir::new("group-cut");
        {
            let storage = Storage::open(d.path()).unwrap();
            let w = GroupWal::start(storage, GroupWalConfig::default(), 0, HashMap::new(), None);
            w.append(rec(0)).unwrap();
            w.begin_compact().unwrap();
            w.append(rec(1)).unwrap(); // pre-cut: covered
            let shard_cut = w.shard_cut(0).unwrap();
            w.append(rec(2)).unwrap(); // post-cut, pre-write: replays
            let mut snap = Value::obj();
            snap.set("upto", 1);
            let file = w
                .segment_writer()
                .write_segment(0, shard_cut, &Value::Obj(snap))
                .unwrap();
            w.append(rec(3)).unwrap(); // post-cut: replays
            w.finish_compact(vec![(0, file, shard_cut)], 1, 1).unwrap();
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert_eq!(loaded.events, vec![rec(2), rec(3)]);
        // The sealed epoch-0 log was GC'd; the pre-cut record in the
        // active log is covered by the segment.
        assert_eq!(loaded.stats.filtered_records, 1);
    }
}

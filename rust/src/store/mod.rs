//! Durable storage substrate — the PostgreSQL stand-in.
//!
//! The paper uses a PostgreSQL instance inside the docker-compose stack
//! to give the (scalable set of) backend workers shared, durable state.
//! What the HOPAAS semantics actually require from that component is:
//!
//! 1. every accepted `ask`/`tell`/`should_prune` mutation survives a
//!    server crash/restart (campaigns run for days on opportunistic
//!    resources — losing told trials wastes real GPU-hours);
//! 2. recovery reconstructs exactly the prefix of acknowledged events.
//!
//! [`Wal`] provides this with a crc32-framed, length-prefixed,
//! append-only log of JSON records plus an optional snapshot + truncate
//! cycle (compaction). A torn/corrupt tail (crash mid-write) is detected
//! by checksum and cleanly discarded; corruption in the *middle* of the
//! log stops recovery at the last valid record, which is the same
//! guarantee a write-ahead log gives.

mod wal;

pub use wal::{Wal, WalError, WalStats};

use crate::json::Value;
use std::path::Path;

/// A record in the event log: a tagged JSON payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    /// Event tag, e.g. `"study"`, `"trial_new"`, `"trial_tell"`.
    pub tag: String,
    pub payload: Value,
}

impl Record {
    pub fn new(tag: impl Into<String>, payload: Value) -> Self {
        Record { tag: tag.into(), payload }
    }

    /// Wire form: `{"t": tag, "p": payload}`.
    pub fn to_value(&self) -> Value {
        let mut o = Value::obj();
        o.set("t", self.tag.as_str());
        o.set("p", self.payload.clone());
        Value::Obj(o)
    }

    pub fn from_value(v: &Value) -> Option<Record> {
        let tag = v.get("t").as_str()?.to_string();
        let payload = v.get("p").clone();
        Some(Record { tag, payload })
    }
}

/// Persistence engine: snapshot file + WAL, atomically compacted.
///
/// Layout under `dir/`:
/// * `snapshot.json` — full-state snapshot (optional)
/// * `wal.log`       — events since the snapshot
pub struct Storage {
    dir: std::path::PathBuf,
    wal: Wal,
}

impl Storage {
    /// Open (or create) storage in `dir`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Storage, WalError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let wal = Wal::open(dir.join("wal.log"))?;
        Ok(Storage { dir, wal })
    }

    /// Load `(snapshot, events-since-snapshot)`.
    pub fn load(&mut self) -> Result<(Option<Value>, Vec<Record>), WalError> {
        let snap_path = self.dir.join("snapshot.json");
        let snapshot = match std::fs::read_to_string(&snap_path) {
            Ok(s) => Some(
                crate::json::parse(&s)
                    .map_err(|e| WalError::Corrupt(format!("snapshot: {e}")))?,
            ),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(WalError::Io(e)),
        };
        let events = self
            .wal
            .replay()?
            .iter()
            .filter_map(Record::from_value)
            .collect();
        Ok((snapshot, events))
    }

    /// Append one event durably (fsync'd before return).
    pub fn append(&mut self, record: &Record) -> Result<(), WalError> {
        self.wal.append(&record.to_value())
    }

    /// Write a snapshot of full state and truncate the WAL atomically
    /// (snapshot is written to a temp file, fsync'd, renamed; only then
    /// is the WAL reset).
    pub fn compact(&mut self, state: &Value) -> Result<(), WalError> {
        let snap_path = self.dir.join("snapshot.json");
        let tmp_path = self.dir.join("snapshot.json.tmp");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp_path)?;
            f.write_all(state.to_string().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp_path, &snap_path)?;
        self.wal.reset()?;
        Ok(())
    }

    /// WAL statistics (for metrics / compaction policy).
    pub fn wal_stats(&self) -> WalStats {
        self.wal.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn rec(tag: &str, n: i64) -> Record {
        let mut o = Value::obj();
        o.set("n", n);
        Record::new(tag, Value::Obj(o))
    }

    #[test]
    fn empty_storage_loads_empty() {
        let d = TempDir::new("store-empty");
        let mut s = Storage::open(d.path()).unwrap();
        let (snap, events) = s.load().unwrap();
        assert!(snap.is_none());
        assert!(events.is_empty());
    }

    #[test]
    fn append_and_reload() {
        let d = TempDir::new("store-append");
        {
            let mut s = Storage::open(d.path()).unwrap();
            for i in 0..10 {
                s.append(&rec("e", i)).unwrap();
            }
        }
        let mut s = Storage::open(d.path()).unwrap();
        let (_, events) = s.load().unwrap();
        assert_eq!(events.len(), 10);
        assert_eq!(events[3], rec("e", 3));
    }

    #[test]
    fn compact_then_more_events() {
        let d = TempDir::new("store-compact");
        {
            let mut s = Storage::open(d.path()).unwrap();
            for i in 0..5 {
                s.append(&rec("pre", i)).unwrap();
            }
            let mut state = Value::obj();
            state.set("count", 5);
            s.compact(&Value::Obj(state)).unwrap();
            s.append(&rec("post", 100)).unwrap();
        }
        let mut s = Storage::open(d.path()).unwrap();
        let (snap, events) = s.load().unwrap();
        assert_eq!(snap.unwrap().get("count").as_i64(), Some(5));
        assert_eq!(events, vec![rec("post", 100)]);
    }

    #[test]
    fn record_roundtrip() {
        let r = rec("trial_tell", 42);
        assert_eq!(Record::from_value(&r.to_value()), Some(r));
    }
}

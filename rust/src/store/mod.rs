//! Durable storage substrate — the PostgreSQL stand-in.
//!
//! The paper uses a PostgreSQL instance inside the docker-compose stack
//! to give the (scalable set of) backend workers shared, durable state.
//! What the HOPAAS semantics actually require from that component is:
//!
//! 1. every accepted `ask`/`tell`/`should_prune` mutation survives a
//!    server crash/restart (campaigns run for days on opportunistic
//!    resources — losing told trials wastes real GPU-hours);
//! 2. recovery reconstructs exactly the prefix of acknowledged events.
//!
//! [`Wal`] provides this with a crc32-framed, length-prefixed,
//! append-only log of JSON records. A torn/corrupt tail (crash
//! mid-write) is detected by checksum and cleanly discarded; corruption
//! in the *middle* of the log stops recovery at the last valid record,
//! which is the same guarantee a write-ahead log gives.
//!
//! [`GroupWal`] layers *group commit* on top: a dedicated writer thread
//! drains a bounded channel of records from all engine shards, frames
//! them in arrival order, fsyncs once per batch, and only then
//! acknowledges each sender — so "acknowledged ⇒ durable" is preserved
//! while N concurrent mutations cost one disk flush instead of N.
//!
//! ## On-disk format v2
//!
//! Layout under the data directory:
//!
//! * `wal.log`, `wal.<E>.log` — epoch-numbered logs. All appends go to
//!   the highest epoch (the *active* log); lower epochs are *sealed*
//!   and only survive a crash inside a compaction window.
//! * `snapshot.shard-<K>.json` — per-shard snapshot segments, each
//!   covering one shard's state up to a per-shard `next_seq` cut.
//! * `MANIFEST.json` — the compaction commit point: format version, the
//!   epoch whose log the segment cuts refer to, the segment list, and
//!   the global `next_seq` at commit time. Its atomic rename is what
//!   makes the segment-set + log-tail cut crash-consistent.
//! * `snapshot.json` — the legacy v1 full-state snapshot. Read (and
//!   honored) only when no manifest exists; deleted by the first v2
//!   compaction.
//!
//! Incremental compaction rotates the log **first** (new epoch), then
//! cuts one segment per shard — each shard paused only for its own cut;
//! the cuts themselves may run concurrently on a side thread pool via
//! [`SegmentWriter`] handles — and finally commits the manifest and
//! garbage-collects sealed logs. The manifest rename stays the single
//! serialization point: it happens only after every segment cut has
//! durably completed, so a crash anywhere in the window still recovers
//! from the previous manifest plus the log tail.
//! Replay applies manifest segments, then every surviving log in epoch
//! order, skipping records the manifest proves are covered: whole logs
//! with `epoch < manifest.epoch`, and records of the manifest epoch
//! with `seq` below both the global and their shard's `next_seq` cut.
//! A crash at *any* point between those steps leaves a directory that
//! replays to exactly the acknowledged state (see
//! `tests/crash_injection.rs`, which drives every kill-point).

mod group;
mod wal;

pub use group::{
    BatchTrace, GroupWal, GroupWalConfig, GroupWalStats, ReplFetch, ReplicationSource, WalAckInfo,
};
pub use wal::{Wal, WalError, WalStats};

use crate::json::Value;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// On-disk format version written into the manifest.
pub const FORMAT_VERSION: u64 = 2;

/// Reserved shard id stamped on fleet records (worker registry / lease
/// events). Fleet state is engine-global, not owned by any study shard;
/// at compaction it is covered by its own `snapshot.fleet.json` segment
/// whose manifest entry carries this id, so the normal per-shard
/// coverage rules apply to fleet records unchanged.
pub const FLEET_SHARD: u32 = u32::MAX;

const MANIFEST_FILE: &str = "MANIFEST.json";
const LEGACY_SNAPSHOT_FILE: &str = "snapshot.json";
const FLEET_SEGMENT_FILE: &str = "snapshot.fleet.json";

/// Fault-injection hook for the crash test harness: called with a named
/// kill-point (`"segment.rename"`, `"gc"`, …) before the corresponding
/// I/O step. Returning `true` "kills" the storage — the current
/// operation fails and every later one errors too, which is how an
/// in-process test simulates a power cut at that exact point.
pub type FaultHook = Arc<dyn Fn(&str) -> bool + Send + Sync>;

/// A record in the event log: a tagged JSON payload plus commit
/// metadata stamped by the WAL writer.
#[derive(Clone, Debug)]
pub struct Record {
    /// Event tag, e.g. `"study"`, `"trial_new"`, `"trial_tell"`.
    pub tag: String,
    pub payload: Value,
    /// Global commit sequence number, stamped by the (single) WAL writer
    /// in file order. 0 until committed; records recovered from logs
    /// written before group commit also read back as 0. Within one shard
    /// `seq` is strictly increasing — the shard-stable replay order.
    pub seq: u64,
    /// Originating engine shard (observability + parallel replay
    /// partitioning + the per-shard compaction cut).
    pub shard: u32,
}

impl Record {
    pub fn new(tag: impl Into<String>, payload: Value) -> Self {
        Record { tag: tag.into(), payload, seq: 0, shard: 0 }
    }

    /// Attach the originating shard index.
    pub fn with_shard(mut self, shard: u32) -> Self {
        self.shard = shard;
        self
    }

    /// Wire form: `{"t": tag, "p": payload, "s": seq, "h": shard}`.
    pub fn to_value(&self) -> Value {
        let mut o = Value::obj();
        o.set("t", self.tag.as_str());
        o.set("p", self.payload.clone());
        o.set("s", self.seq);
        o.set("h", self.shard);
        Value::Obj(o)
    }

    pub fn from_value(v: &Value) -> Option<Record> {
        let tag = v.get("t").as_str()?.to_string();
        let payload = v.get("p").clone();
        let seq = v.get("s").as_u64().unwrap_or(0);
        let shard = v.get("h").as_u64().unwrap_or(0) as u32;
        Some(Record { tag, payload, seq, shard })
    }
}

/// Commit metadata (`seq`, `shard`) is bookkeeping, not identity: two
/// records are the same event if tag and payload match, whichever batch
/// they were flushed in.
impl PartialEq for Record {
    fn eq(&self, other: &Self) -> bool {
        self.tag == other.tag && self.payload == other.payload
    }
}

/// What one recovery pass observed. Mirrored into `/api/stats` and the
/// `hopaas_wal_recovered_records` / `hopaas_wal_truncated_records`
/// metric gauges so operators can see whether a restart lost a tail.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryStats {
    /// Records replayed into the engine (survived the manifest filter).
    pub recovered_records: u64,
    /// Records skipped because the manifest proves a segment covers them.
    pub filtered_records: u64,
    /// Torn-tail incidents across all logs (≤ 1 per log file).
    pub truncated_records: u64,
    /// Bytes discarded with those torn tails.
    pub truncated_bytes: u64,
    /// Snapshot segments applied.
    pub segments: u64,
    /// Replayed records that referenced an unknown study/trial (their
    /// parent record was lost in a torn tail) and were dropped.
    pub orphan_records: u64,
    /// Nonzero commit `seq`s that went backwards in file order — should
    /// be 0; anything else indicates log corruption past the CRC layer.
    pub seq_order_violations: u64,
}

/// Everything recovery needs, produced by [`Storage::load`].
pub struct LoadedState {
    /// Parsed `MANIFEST.json`, when the directory is format v2.
    pub manifest: Option<Value>,
    /// Parsed segment files, in manifest order.
    pub segments: Vec<Value>,
    /// Legacy v1 snapshot (only when no manifest exists).
    pub snapshot: Option<Value>,
    /// Events to replay, in global file (= commit) order, already
    /// filtered down to the ones the segments do *not* cover.
    pub events: Vec<Record>,
    pub stats: RecoveryStats,
}

/// State shared between the [`Storage`] owner (normally the WAL writer
/// thread) and the [`SegmentWriter`] handles cutting snapshot segments
/// on compaction-pool threads: the data directory, the fault-injection
/// hook, and the killed flag. The flag is atomic so a kill-point firing
/// on *any* thread also fails every later operation on every other
/// handle — one process, one simulated power cut.
struct StorageShared {
    dir: PathBuf,
    hook: Option<FaultHook>,
    /// Set when a fault hook fired: the storage behaves like a crashed
    /// process — every further operation fails.
    killed: AtomicBool,
}

impl StorageShared {
    /// Consult the fault hook at a named kill-point (thread-safe).
    fn fault(&self, point: &str) -> Result<(), WalError> {
        if self.killed.load(Ordering::Relaxed) {
            return Err(WalError::Corrupt("storage killed by fault injection".into()));
        }
        if let Some(hook) = &self.hook {
            if hook(point) {
                self.killed.store(true, Ordering::Relaxed);
                return Err(WalError::Corrupt(format!("fault injected at {point}")));
            }
        }
        Ok(())
    }

    /// fsync the data directory itself. POSIX gives renames and unlinks
    /// no durability ordering without this: a power cut could otherwise
    /// persist the `MANIFEST.json` rename but not a segment rename it
    /// depends on, leaving a manifest that references missing files —
    /// an unrecoverable startup instead of a clean replay. (No-op on
    /// non-unix targets, which cannot sync a directory handle.)
    fn sync_dir(&self) -> Result<(), WalError> {
        #[cfg(unix)]
        std::fs::File::open(&self.dir)?.sync_all()?;
        Ok(())
    }

    /// Durably write one snapshot segment (tmp file → fsync → rename →
    /// directory fsync). Safe from any thread: segment files are
    /// per-shard, so concurrent cuts of *different* shards never touch
    /// the same path.
    fn write_segment(
        &self,
        shard: u32,
        next_seq: u64,
        studies: &Value,
    ) -> Result<String, WalError> {
        self.fault("segment.write")?;
        let name = segment_file(shard);
        let tmp = self.dir.join(format!("{name}.tmp"));
        let mut o = Value::obj();
        o.set("shard", shard).set("next_seq", next_seq).set("studies", studies.clone());
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(Value::Obj(o).to_string().as_bytes())?;
            self.fault("segment.sync")?;
            f.sync_all()?;
        }
        self.fault("segment.rename")?;
        std::fs::rename(&tmp, self.dir.join(&name))?;
        // The manifest will reference this file; its rename must be
        // durable before the manifest's is.
        self.sync_dir()?;
        Ok(name)
    }
}

/// A cloneable handle that can cut snapshot segments from any thread —
/// the seam the parallel compaction pipeline uses to move segment I/O
/// off the WAL writer thread while the manifest commit stays behind it.
#[derive(Clone)]
pub struct SegmentWriter {
    shared: Arc<StorageShared>,
}

impl SegmentWriter {
    /// As [`Storage::write_segment`], callable concurrently for
    /// distinct shards.
    pub fn write_segment(
        &self,
        shard: u32,
        next_seq: u64,
        studies: &Value,
    ) -> Result<String, WalError> {
        self.shared.write_segment(shard, next_seq, studies)
    }
}

/// Persistence engine: epoch logs + per-shard snapshot segments, with a
/// manifest as the compaction commit point. See the module docs for the
/// on-disk layout and replay rules.
pub struct Storage {
    shared: Arc<StorageShared>,
    /// Active (highest-epoch) log; all appends land here.
    wal: Wal,
    epoch: u64,
    /// Lower-epoch logs not yet garbage-collected, in epoch order.
    sealed: Vec<(u64, PathBuf)>,
}

/// Path of the log with `epoch` under `dir`. Epoch 0 keeps the v1 name
/// so pre-manifest directories open unchanged.
fn log_path(dir: &Path, epoch: u64) -> PathBuf {
    if epoch == 0 {
        dir.join("wal.log")
    } else {
        dir.join(format!("wal.{epoch}.log"))
    }
}

/// Parse a log file name back to its epoch.
fn log_epoch(name: &str) -> Option<u64> {
    if name == "wal.log" {
        return Some(0);
    }
    let rest = name.strip_prefix("wal.")?.strip_suffix(".log")?;
    rest.parse().ok()
}

fn segment_file(shard: u32) -> String {
    if shard == FLEET_SHARD {
        FLEET_SEGMENT_FILE.to_string()
    } else {
        format!("snapshot.shard-{shard}.json")
    }
}

/// Read the current snapshot bundle of `dir` — `MANIFEST.json` plus the
/// raw text of every segment file it references — for shipping to a
/// bootstrapping follower: `{"manifest": ..., "files": [{"name", "data"}]}`.
/// A concurrent compaction can GC a segment between the manifest read
/// and the file read; the whole read is retried against the (new)
/// manifest in that case. `{"manifest": null}` when the directory has
/// never been compacted — the follower then starts from seq 0 and
/// receives everything over the stream.
pub fn read_snapshot_bundle(dir: impl AsRef<Path>) -> Result<Value, WalError> {
    let dir = dir.as_ref();
    for _ in 0..8 {
        let text = match std::fs::read_to_string(dir.join(MANIFEST_FILE)) {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let mut o = Value::obj();
                o.set("manifest", Value::Null).set("files", Value::Arr(Vec::new()));
                return Ok(Value::Obj(o));
            }
            Err(e) => return Err(WalError::Io(e)),
        };
        let manifest = crate::json::parse(&text)
            .map_err(|e| WalError::Corrupt(format!("manifest: {e}")))?;
        let mut files = Vec::new();
        let mut raced = false;
        for seg in manifest.get("segments").as_arr().unwrap_or(&[]) {
            let Some(name) = seg.get("file").as_str() else { continue };
            match std::fs::read_to_string(dir.join(name)) {
                Ok(data) => {
                    let mut f = Value::obj();
                    f.set("name", name).set("data", data);
                    files.push(Value::Obj(f));
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                    raced = true;
                    break;
                }
                Err(e) => return Err(WalError::Io(e)),
            }
        }
        if raced {
            continue;
        }
        let mut o = Value::obj();
        o.set("manifest", manifest).set("files", Value::Arr(files));
        return Ok(Value::Obj(o));
    }
    Err(WalError::Corrupt("snapshot bundle kept racing compaction".into()))
}

/// Install a [`read_snapshot_bundle`] payload into an (empty) follower
/// data directory: segment files first, each fsynced, then the manifest
/// — the same write-ordering discipline compaction uses, so a crash
/// mid-install leaves either no manifest (bootstrap restarts cleanly)
/// or a manifest whose segments are all durable. The manifest's `epoch`
/// is rewritten to 0: the follower's own log numbering starts fresh,
/// and an inherited higher epoch would mark every locally appended
/// record as covered.
pub fn install_snapshot_bundle(dir: impl AsRef<Path>, bundle: &Value) -> Result<(), WalError> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    for f in bundle.get("files").as_arr().unwrap_or(&[]) {
        let (Some(name), Some(data)) = (f.get("name").as_str(), f.get("data").as_str()) else {
            return Err(WalError::Corrupt("bundle file without name/data".into()));
        };
        if name.contains('/') || name.contains("..") {
            return Err(WalError::Corrupt(format!("bundle file name escapes dir: {name}")));
        }
        use std::io::Write;
        let mut file = std::fs::File::create(dir.join(name))?;
        file.write_all(data.as_bytes())?;
        file.sync_all()?;
    }
    let manifest = bundle.get("manifest");
    if manifest.is_null() {
        return Ok(());
    }
    let mut m = manifest.clone();
    if let Value::Obj(o) = &mut m {
        o.set("epoch", 0u64);
    }
    let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(m.to_string().as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, dir.join(MANIFEST_FILE))?;
    #[cfg(unix)]
    std::fs::File::open(dir)?.sync_all()?;
    Ok(())
}

impl Storage {
    /// Open (or create) storage in `dir`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Storage, WalError> {
        Storage::open_with_hook(dir, None)
    }

    /// As [`Storage::open`], with a fault-injection hook consulted at
    /// every named kill-point (crash test harness; `None` in production).
    pub fn open_with_hook(
        dir: impl AsRef<Path>,
        hook: Option<FaultHook>,
    ) -> Result<Storage, WalError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let mut epochs: Vec<u64> = Vec::new();
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            if let Some(e) = entry.file_name().to_str().and_then(log_epoch) {
                epochs.push(e);
            }
        }
        epochs.sort_unstable();
        let active = epochs.last().copied().unwrap_or(0);
        let sealed = epochs
            .iter()
            .filter(|&&e| e != active)
            .map(|&e| (e, log_path(&dir, e)))
            .collect();
        let wal = Wal::open(log_path(&dir, active))?;
        let shared = Arc::new(StorageShared { dir, hook, killed: AtomicBool::new(false) });
        Ok(Storage { shared, wal, epoch: active, sealed })
    }

    /// A handle that cuts snapshot segments from any thread (the
    /// parallel compaction pipeline's side pool).
    pub fn segment_writer(&self) -> SegmentWriter {
        SegmentWriter { shared: self.shared.clone() }
    }

    fn sync_dir(&self) -> Result<(), WalError> {
        self.shared.sync_dir()
    }

    /// Consult the fault hook at a named kill-point.
    fn fault(&self, point: &str) -> Result<(), WalError> {
        self.shared.fault(point)
    }

    /// Consult the fault hook at a named kill-point from layers above
    /// raw file I/O — the replication publish/ack/wake points the WAL
    /// writer fires between fsync and acknowledgement. Public so the
    /// group-commit writer can model a crash in the replication window
    /// with the same one-process-one-power-cut semantics as the disk
    /// kill-points.
    pub fn fault_point(&self, point: &str) -> Result<(), WalError> {
        self.shared.fault(point)
    }

    /// Load segments / legacy snapshot / filtered events. Replays every
    /// surviving log in epoch order; see the module docs for the
    /// coverage rules the manifest establishes.
    pub fn load(&mut self) -> Result<LoadedState, WalError> {
        let mut stats = RecoveryStats::default();

        // Manifest (v2) — its presence supersedes the legacy snapshot.
        let manifest = match std::fs::read_to_string(self.shared.dir.join(MANIFEST_FILE)) {
            Ok(s) => Some(
                crate::json::parse(&s)
                    .map_err(|e| WalError::Corrupt(format!("manifest: {e}")))?,
            ),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(WalError::Io(e)),
        };

        let mut segments = Vec::new();
        let mut manifest_epoch = 0u64;
        let mut manifest_next_seq = 0u64;
        // Per-shard `next_seq` cuts, indexed by recorded shard id.
        let mut shard_cut: std::collections::HashMap<u32, u64> =
            std::collections::HashMap::new();
        if let Some(m) = &manifest {
            manifest_epoch = m.get("epoch").as_u64().unwrap_or(0);
            manifest_next_seq = m.get("next_seq").as_u64().unwrap_or(0);
            for seg in m.get("segments").as_arr().unwrap_or(&[]) {
                let file = seg
                    .get("file")
                    .as_str()
                    .ok_or_else(|| WalError::Corrupt("manifest segment without file".into()))?;
                let text = std::fs::read_to_string(self.shared.dir.join(file))
                    .map_err(|e| WalError::Corrupt(format!("segment {file}: {e}")))?;
                let value = crate::json::parse(&text)
                    .map_err(|e| WalError::Corrupt(format!("segment {file}: {e}")))?;
                let shard = seg.get("shard").as_u64().unwrap_or(0) as u32;
                shard_cut.insert(shard, seg.get("next_seq").as_u64().unwrap_or(0));
                segments.push(value);
                stats.segments += 1;
            }
        }

        // Legacy v1 snapshot: only authoritative while no manifest exists.
        let snapshot = if manifest.is_some() {
            None
        } else {
            match std::fs::read_to_string(self.shared.dir.join(LEGACY_SNAPSHOT_FILE)) {
                Ok(s) => Some(
                    crate::json::parse(&s)
                        .map_err(|e| WalError::Corrupt(format!("snapshot: {e}")))?,
                ),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
                Err(e) => return Err(WalError::Io(e)),
            }
        };

        // Replay sealed logs (epoch order), then the active log.
        let mut events = Vec::new();
        let mut absorb = |epoch: u64, values: Vec<Value>, wal_stats: WalStats| {
            stats.truncated_bytes += wal_stats.truncated_bytes;
            stats.truncated_records += wal_stats.truncations;
            for v in values {
                let Some(rec) = Record::from_value(&v) else { continue };
                let covered = manifest.is_some()
                    && (epoch < manifest_epoch
                        || (epoch == manifest_epoch
                            && rec.seq < manifest_next_seq
                            && rec.seq < shard_cut.get(&rec.shard).copied().unwrap_or(0)));
                if covered {
                    stats.filtered_records += 1;
                } else {
                    stats.recovered_records += 1;
                    events.push(rec);
                }
            }
        };
        for (epoch, path) in &self.sealed {
            let mut sealed_wal = Wal::open(path.clone())?;
            let values = sealed_wal.replay()?;
            absorb(*epoch, values, sealed_wal.stats());
        }
        let values = self.wal.replay()?;
        absorb(self.epoch, values, self.wal.stats());

        // Verify the global commit order (nonzero seqs must not go
        // backwards across the epoch-ordered concatenation).
        let mut last_seq = 0u64;
        for rec in &events {
            if rec.seq > 0 {
                if rec.seq < last_seq {
                    stats.seq_order_violations += 1;
                }
                last_seq = last_seq.max(rec.seq);
            }
        }

        Ok(LoadedState { manifest, segments, snapshot, events, stats })
    }

    /// Append one event durably (fsync'd before return).
    pub fn append(&mut self, record: &Record) -> Result<(), WalError> {
        self.append_nosync(record)?;
        self.sync()
    }

    /// Append one event without flushing; durable only after
    /// [`Storage::sync`]. The group-commit writer frames a whole batch
    /// this way and pays for a single fsync.
    pub fn append_nosync(&mut self, record: &Record) -> Result<(), WalError> {
        self.fault("append")?;
        self.wal.append_nosync(&record.to_value())
    }

    /// Flush all appended events to stable storage.
    pub fn sync(&mut self) -> Result<(), WalError> {
        self.fault("sync")?;
        self.wal.sync()
    }

    /// Roll the log back to a previously captured [`Storage::wal_stats`]
    /// mark, discarding partially written (never acknowledged) frames.
    pub fn rollback(&mut self, mark: WalStats) -> Result<(), WalError> {
        self.fault("rollback")?;
        self.wal.truncate_to(mark)
    }

    /// Phase 1 of incremental compaction: seal the active log and start
    /// a new epoch. Every record appended from here on lands in the new
    /// log, so the per-shard cuts taken in phase 2 fully cover the
    /// sealed logs — which is what lets phase 3 delete them.
    pub fn begin_compact(&mut self) -> Result<(), WalError> {
        self.fault("rotate")?;
        let next_epoch = self.epoch + 1;
        let new_wal = Wal::open(log_path(&self.shared.dir, next_epoch))?;
        // Make the new log's directory entry durable before anything is
        // acknowledged out of it.
        self.sync_dir()?;
        let old_wal = std::mem::replace(&mut self.wal, new_wal);
        self.sealed.push((self.epoch, old_wal.path().to_path_buf()));
        self.epoch = next_epoch;
        Ok(())
    }

    /// Phase 2, once per shard: durably write `snapshot.shard-<K>.json`
    /// covering that shard's state up to `next_seq` (tmp file → fsync →
    /// rename). Returns the file name for the manifest. Also available
    /// through [`Storage::segment_writer`] handles, which let the
    /// compaction pipeline cut several shards' segments concurrently.
    pub fn write_segment(
        &mut self,
        shard: u32,
        next_seq: u64,
        studies: &Value,
    ) -> Result<String, WalError> {
        self.shared.write_segment(shard, next_seq, studies)
    }

    /// Phase 3: commit the compaction by atomically renaming the
    /// manifest into place, then garbage-collect the sealed logs and
    /// the legacy v1 snapshot. A crash after the rename loses nothing —
    /// replay skips the covered records the GC would have deleted.
    pub fn finish_compact(
        &mut self,
        segments: &[(u32, String, u64)],
        next_seq: u64,
        next_trial_id: u64,
        next_study_id: u64,
    ) -> Result<(), WalError> {
        self.fault("manifest.write")?;
        let mut m = Value::obj();
        m.set("version", FORMAT_VERSION)
            .set("epoch", self.epoch)
            .set("next_seq", next_seq)
            .set("next_trial_id", next_trial_id)
            .set("next_study_id", next_study_id)
            .set(
                "segments",
                Value::Arr(
                    segments
                        .iter()
                        .map(|(shard, file, cut)| {
                            let mut s = Value::obj();
                            s.set("shard", *shard)
                                .set("file", file.as_str())
                                .set("next_seq", *cut);
                            Value::Obj(s)
                        })
                        .collect(),
                ),
            );
        let tmp = self.shared.dir.join(format!("{MANIFEST_FILE}.tmp"));
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(Value::Obj(m).to_string().as_bytes())?;
            f.sync_all()?;
        }
        self.fault("manifest.rename")?;
        std::fs::rename(&tmp, self.shared.dir.join(MANIFEST_FILE))?;
        // The rename is the commit point — fsync the directory so power
        // loss cannot roll it back; everything below is GC.
        self.sync_dir()?;
        self.fault("gc")?;
        match std::fs::remove_file(self.shared.dir.join(LEGACY_SNAPSHOT_FILE)) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(WalError::Io(e)),
        }
        // Segment files the new manifest no longer references — shards
        // dropped by a smaller --shards, or .tmp leftovers of a crashed
        // cut — are litter; clear them so the directory always reflects
        // exactly the live state.
        let live: std::collections::HashSet<&str> =
            segments.iter().map(|(_, file, _)| file.as_str()).collect();
        for entry in std::fs::read_dir(&self.shared.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let stale = (name.starts_with("snapshot.shard-")
                || name.starts_with(FLEET_SEGMENT_FILE))
                && (name.ends_with(".json.tmp")
                    || (name.ends_with(".json") && !live.contains(name)));
            if stale {
                match std::fs::remove_file(entry.path()) {
                    Ok(()) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => return Err(WalError::Io(e)),
                }
            }
        }
        while let Some((epoch, path)) = self.sealed.pop() {
            match std::fs::remove_file(&path) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    // Keep tracking the log we failed to delete; the GC
                    // retries at the next compaction, and replay skips
                    // its covered records either way.
                    self.sealed.push((epoch, path));
                    return Err(WalError::Io(e));
                }
            }
        }
        // Unlink durability is best-effort-by-ordering only: a sealed
        // log resurrected by power loss is skipped at replay anyway.
        self.sync_dir()
    }

    /// Statistics of the *active* log (for metrics / compaction policy).
    pub fn wal_stats(&self) -> WalStats {
        self.wal.stats()
    }

    /// Current log epoch (diagnostics / tests).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Data directory.
    pub fn dir(&self) -> &Path {
        &self.shared.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn rec(tag: &str, n: i64) -> Record {
        let mut o = Value::obj();
        o.set("n", n);
        Record::new(tag, Value::Obj(o))
    }

    /// A sequenced record, as the group-commit writer would stamp it.
    fn srec(tag: &str, n: i64, seq: u64, shard: u32) -> Record {
        let mut r = rec(tag, n).with_shard(shard);
        r.seq = seq;
        r
    }

    #[test]
    fn empty_storage_loads_empty() {
        let d = TempDir::new("store-empty");
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert!(loaded.snapshot.is_none());
        assert!(loaded.manifest.is_none());
        assert!(loaded.events.is_empty());
        assert_eq!(loaded.stats.recovered_records, 0);
    }

    #[test]
    fn append_and_reload() {
        let d = TempDir::new("store-append");
        {
            let mut s = Storage::open(d.path()).unwrap();
            for i in 0..10 {
                s.append(&rec("e", i)).unwrap();
            }
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert_eq!(loaded.events.len(), 10);
        assert_eq!(loaded.events[3], rec("e", 3));
        assert_eq!(loaded.stats.recovered_records, 10);
    }

    #[test]
    fn legacy_v1_snapshot_honored_without_manifest() {
        let d = TempDir::new("store-v1");
        {
            let mut state = Value::obj();
            state.set("count", 5);
            std::fs::write(
                d.path().join(LEGACY_SNAPSHOT_FILE),
                Value::Obj(state).to_string(),
            )
            .unwrap();
            let mut s = Storage::open(d.path()).unwrap();
            s.append(&rec("post", 100)).unwrap();
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert_eq!(loaded.snapshot.unwrap().get("count").as_i64(), Some(5));
        assert_eq!(loaded.events, vec![rec("post", 100)]);
    }

    #[test]
    fn incremental_compact_cut_is_exact() {
        let d = TempDir::new("store-inc");
        {
            let mut s = Storage::open(d.path()).unwrap();
            // Two shards committed records 0..4.
            for i in 0..5u64 {
                s.append(&srec("e", i as i64, i, (i % 2) as u32)).unwrap();
            }
            s.begin_compact().unwrap();
            // Shard 0 commits one more record *after* rotation, before
            // its own cut: covered by its segment.
            s.append(&srec("e", 100, 5, 0)).unwrap();
            let mut seg0 = Value::obj();
            seg0.set("marker", 0);
            let f0 = s.write_segment(0, 6, &Value::Obj(seg0)).unwrap();
            // Shard 1 commits after its cut: must replay.
            let mut seg1 = Value::obj();
            seg1.set("marker", 1);
            let f1 = s.write_segment(1, 5, &Value::Obj(seg1)).unwrap();
            s.append(&srec("e", 200, 6, 1)).unwrap();
            s.finish_compact(&[(0, f0, 6), (1, f1, 5)], 7, 1, 1).unwrap();
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert_eq!(loaded.segments.len(), 2);
        assert_eq!(loaded.events, vec![srec("e", 200, 6, 1)]);
        // The sealed log was GC'd; of the two post-rotation records,
        // shard 0's pre-cut one is covered by its segment.
        assert_eq!(loaded.stats.filtered_records, 1);
        assert_eq!(loaded.stats.recovered_records, 1);
        // Sealed epoch-0 log was garbage-collected.
        assert!(!d.path().join("wal.log").exists());
        assert!(d.path().join("wal.1.log").exists());
    }

    #[test]
    fn crash_before_gc_replays_without_duplicates() {
        let d = TempDir::new("store-crash-gc");
        let killed = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        {
            let k = killed.clone();
            let hook: FaultHook = Arc::new(move |point: &str| {
                if point == "gc" {
                    k.store(true, std::sync::atomic::Ordering::Relaxed);
                    true
                } else {
                    false
                }
            });
            let mut s = Storage::open_with_hook(d.path(), Some(hook)).unwrap();
            for i in 0..4u64 {
                s.append(&srec("e", i as i64, i, 0)).unwrap();
            }
            s.begin_compact().unwrap();
            let mut seg = Value::obj();
            seg.set("marker", 0);
            let f = s.write_segment(0, 4, &Value::Obj(seg)).unwrap();
            // Dies at the GC step: manifest committed, old log remains.
            assert!(s.finish_compact(&[(0, f, 4)], 4, 1, 1).is_err());
            assert!(killed.load(std::sync::atomic::Ordering::Relaxed));
            // A killed storage refuses everything, like a dead process.
            assert!(s.append(&rec("e", 9)).is_err());
        }
        assert!(d.path().join("wal.log").exists(), "GC never ran");
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        // The sealed log's records are all covered by the manifest.
        assert!(loaded.events.is_empty());
        assert_eq!(loaded.stats.filtered_records, 4);
        assert_eq!(loaded.segments.len(), 1);
        // The next compaction GCs the leftover.
        s.begin_compact().unwrap();
        let mut seg = Value::obj();
        seg.set("marker", 0);
        let f = s.write_segment(0, 4, &Value::Obj(seg)).unwrap();
        s.finish_compact(&[(0, f, 4)], 4, 1, 1).unwrap();
        assert!(!d.path().join("wal.log").exists());
        assert!(!d.path().join("wal.1.log").exists());
        assert!(d.path().join("wal.2.log").exists());
    }

    #[test]
    fn shrinking_shard_count_gcs_stale_segments() {
        let d = TempDir::new("store-shrink");
        let mut s = Storage::open(d.path()).unwrap();
        s.append(&srec("e", 0, 0, 0)).unwrap();
        // First compaction under a 4-shard layout.
        s.begin_compact().unwrap();
        let mut segs = Vec::new();
        for shard in 0..4u32 {
            let f = s.write_segment(shard, 1, &Value::Obj(Value::obj())).unwrap();
            segs.push((shard, f, 1));
        }
        s.finish_compact(&segs, 1, 1, 1).unwrap();
        for shard in 0..4 {
            assert!(d.path().join(segment_file(shard)).exists());
        }
        // Second compaction after shrinking to 2 shards: the manifest
        // references only shards 0–1, and the stale 2–3 files go away.
        s.begin_compact().unwrap();
        let mut segs = Vec::new();
        for shard in 0..2u32 {
            let f = s.write_segment(shard, 1, &Value::Obj(Value::obj())).unwrap();
            segs.push((shard, f, 1));
        }
        s.finish_compact(&segs, 1, 1, 1).unwrap();
        assert!(d.path().join(segment_file(0)).exists());
        assert!(d.path().join(segment_file(1)).exists());
        assert!(!d.path().join(segment_file(2)).exists());
        assert!(!d.path().join(segment_file(3)).exists());
    }

    #[test]
    fn crash_before_manifest_keeps_full_log() {
        let d = TempDir::new("store-crash-pre-manifest");
        {
            let hook: FaultHook = Arc::new(|point: &str| point == "manifest.rename");
            let mut s = Storage::open_with_hook(d.path(), Some(hook)).unwrap();
            for i in 0..4u64 {
                s.append(&srec("e", i as i64, i, 0)).unwrap();
            }
            s.begin_compact().unwrap();
            let mut seg = Value::obj();
            seg.set("marker", 0);
            let f = s.write_segment(0, 4, &Value::Obj(seg)).unwrap();
            assert!(s.finish_compact(&[(0, f, 4)], 4, 1, 1).is_err());
        }
        // No manifest → the orphan segment is ignored, the log is whole.
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert!(loaded.manifest.is_none());
        assert_eq!(loaded.events.len(), 4);
        assert_eq!(loaded.stats.filtered_records, 0);
    }

    #[test]
    fn segment_writer_cuts_concurrently_and_shares_the_kill_flag() {
        let d = TempDir::new("store-segwriter");
        {
            let mut s = Storage::open(d.path()).unwrap();
            for i in 0..4u64 {
                s.append(&srec("e", i as i64, i, (i % 4) as u32)).unwrap();
            }
            s.begin_compact().unwrap();
            let writer = s.segment_writer();
            // Four shards cut on four threads at once.
            let files: Vec<(u32, String, u64)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..4u32)
                    .map(|shard| {
                        let w = writer.clone();
                        scope.spawn(move || {
                            let mut seg = Value::obj();
                            seg.set("marker", shard);
                            let f = w.write_segment(shard, 4, &Value::Obj(seg)).unwrap();
                            (shard, f, 4u64)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            s.finish_compact(&files, 4, 1, 1).unwrap();
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert_eq!(loaded.segments.len(), 4);
        assert!(loaded.events.is_empty(), "all records covered by the cuts");

        // A kill-point firing on a pool-thread handle fails the owning
        // Storage too — one process, one power cut.
        let d2 = TempDir::new("store-segwriter-kill");
        let hook: FaultHook = Arc::new(|point: &str| point == "segment.rename");
        let mut s = Storage::open_with_hook(d2.path(), Some(hook)).unwrap();
        s.append(&srec("e", 0, 0, 0)).unwrap();
        s.begin_compact().unwrap();
        let w = s.segment_writer();
        assert!(w.write_segment(0, 1, &Value::Obj(Value::obj())).is_err());
        assert!(s.append(&srec("e", 1, 1, 0)).is_err(), "owner shares the kill");
    }

    #[test]
    fn record_roundtrip() {
        let r = rec("trial_tell", 42);
        assert_eq!(Record::from_value(&r.to_value()), Some(r));
    }

    #[test]
    fn record_commit_metadata_roundtrips_but_is_not_identity() {
        let mut r = rec("trial_tell", 7).with_shard(3);
        r.seq = 99;
        let back = Record::from_value(&r.to_value()).unwrap();
        assert_eq!(back.seq, 99);
        assert_eq!(back.shard, 3);
        // Equality ignores commit metadata.
        assert_eq!(back, rec("trial_tell", 7));
        // Pre-group-commit wire form (no "s"/"h") defaults to 0.
        let legacy = rec("trial_tell", 7);
        let mut v = Value::obj();
        v.set("t", "trial_tell").set("p", legacy.payload.clone());
        let parsed = Record::from_value(&Value::Obj(v)).unwrap();
        assert_eq!(parsed.seq, 0);
        assert_eq!(parsed.shard, 0);
    }

    #[test]
    fn seq_order_violation_detected() {
        let d = TempDir::new("store-seq");
        {
            let mut s = Storage::open(d.path()).unwrap();
            s.append(&srec("e", 0, 5, 0)).unwrap();
            s.append(&srec("e", 1, 3, 0)).unwrap(); // goes backwards
        }
        let mut s = Storage::open(d.path()).unwrap();
        let loaded = s.load().unwrap();
        assert_eq!(loaded.stats.seq_order_violations, 1);
        assert_eq!(loaded.events.len(), 2, "records still recovered");
    }

    #[test]
    fn log_epoch_naming() {
        assert_eq!(log_epoch("wal.log"), Some(0));
        assert_eq!(log_epoch("wal.7.log"), Some(7));
        assert_eq!(log_epoch("wal.12.log"), Some(12));
        assert_eq!(log_epoch("snapshot.json"), None);
        assert_eq!(log_epoch("wal.x.log"), None);
        let d = std::path::Path::new("/tmp");
        assert_eq!(log_path(d, 0), d.join("wal.log"));
        assert_eq!(log_path(d, 3), d.join("wal.3.log"));
    }
}

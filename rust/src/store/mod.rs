//! Durable storage substrate — the PostgreSQL stand-in.
//!
//! The paper uses a PostgreSQL instance inside the docker-compose stack
//! to give the (scalable set of) backend workers shared, durable state.
//! What the HOPAAS semantics actually require from that component is:
//!
//! 1. every accepted `ask`/`tell`/`should_prune` mutation survives a
//!    server crash/restart (campaigns run for days on opportunistic
//!    resources — losing told trials wastes real GPU-hours);
//! 2. recovery reconstructs exactly the prefix of acknowledged events.
//!
//! [`Wal`] provides this with a crc32-framed, length-prefixed,
//! append-only log of JSON records plus an optional snapshot + truncate
//! cycle (compaction). A torn/corrupt tail (crash mid-write) is detected
//! by checksum and cleanly discarded; corruption in the *middle* of the
//! log stops recovery at the last valid record, which is the same
//! guarantee a write-ahead log gives.
//!
//! [`GroupWal`] layers *group commit* on top: a dedicated writer thread
//! drains a bounded channel of records from all engine shards, frames
//! them in arrival order, fsyncs once per batch, and only then
//! acknowledges each sender — so "acknowledged ⇒ durable" is preserved
//! while N concurrent mutations cost one disk flush instead of N.

mod group;
mod wal;

pub use group::{GroupWal, GroupWalConfig, GroupWalStats};
pub use wal::{Wal, WalError, WalStats};

use crate::json::Value;
use std::path::Path;

/// A record in the event log: a tagged JSON payload plus commit
/// metadata stamped by the WAL writer.
#[derive(Clone, Debug)]
pub struct Record {
    /// Event tag, e.g. `"study"`, `"trial_new"`, `"trial_tell"`.
    pub tag: String,
    pub payload: Value,
    /// Global commit sequence number, stamped by the (single) WAL writer
    /// in file order. 0 until committed; records recovered from logs
    /// written before group commit also read back as 0. Within one shard
    /// `seq` is strictly increasing — the shard-stable replay order.
    pub seq: u64,
    /// Originating engine shard (observability + future parallel replay).
    pub shard: u32,
}

impl Record {
    pub fn new(tag: impl Into<String>, payload: Value) -> Self {
        Record { tag: tag.into(), payload, seq: 0, shard: 0 }
    }

    /// Attach the originating shard index.
    pub fn with_shard(mut self, shard: u32) -> Self {
        self.shard = shard;
        self
    }

    /// Wire form: `{"t": tag, "p": payload, "s": seq, "h": shard}`.
    pub fn to_value(&self) -> Value {
        let mut o = Value::obj();
        o.set("t", self.tag.as_str());
        o.set("p", self.payload.clone());
        o.set("s", self.seq);
        o.set("h", self.shard);
        Value::Obj(o)
    }

    pub fn from_value(v: &Value) -> Option<Record> {
        let tag = v.get("t").as_str()?.to_string();
        let payload = v.get("p").clone();
        let seq = v.get("s").as_u64().unwrap_or(0);
        let shard = v.get("h").as_u64().unwrap_or(0) as u32;
        Some(Record { tag, payload, seq, shard })
    }
}

/// Commit metadata (`seq`, `shard`) is bookkeeping, not identity: two
/// records are the same event if tag and payload match, whichever batch
/// they were flushed in.
impl PartialEq for Record {
    fn eq(&self, other: &Self) -> bool {
        self.tag == other.tag && self.payload == other.payload
    }
}

/// Persistence engine: snapshot file + WAL, atomically compacted.
///
/// Layout under `dir/`:
/// * `snapshot.json` — full-state snapshot (optional)
/// * `wal.log`       — events since the snapshot
pub struct Storage {
    dir: std::path::PathBuf,
    wal: Wal,
}

impl Storage {
    /// Open (or create) storage in `dir`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Storage, WalError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let wal = Wal::open(dir.join("wal.log"))?;
        Ok(Storage { dir, wal })
    }

    /// Load `(snapshot, events-since-snapshot)`.
    pub fn load(&mut self) -> Result<(Option<Value>, Vec<Record>), WalError> {
        let snap_path = self.dir.join("snapshot.json");
        let snapshot = match std::fs::read_to_string(&snap_path) {
            Ok(s) => Some(
                crate::json::parse(&s)
                    .map_err(|e| WalError::Corrupt(format!("snapshot: {e}")))?,
            ),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(WalError::Io(e)),
        };
        let events = self
            .wal
            .replay()?
            .iter()
            .filter_map(Record::from_value)
            .collect();
        Ok((snapshot, events))
    }

    /// Append one event durably (fsync'd before return).
    pub fn append(&mut self, record: &Record) -> Result<(), WalError> {
        self.wal.append(&record.to_value())
    }

    /// Append one event without flushing; durable only after
    /// [`Storage::sync`]. The group-commit writer frames a whole batch
    /// this way and pays for a single fsync.
    pub fn append_nosync(&mut self, record: &Record) -> Result<(), WalError> {
        self.wal.append_nosync(&record.to_value())
    }

    /// Flush all appended events to stable storage.
    pub fn sync(&mut self) -> Result<(), WalError> {
        self.wal.sync()
    }

    /// Roll the log back to a previously captured [`Storage::wal_stats`]
    /// mark, discarding partially written (never acknowledged) frames.
    pub fn rollback(&mut self, mark: WalStats) -> Result<(), WalError> {
        self.wal.truncate_to(mark)
    }

    /// Write a snapshot of full state and truncate the WAL atomically
    /// (snapshot is written to a temp file, fsync'd, renamed; only then
    /// is the WAL reset).
    pub fn compact(&mut self, state: &Value) -> Result<(), WalError> {
        let snap_path = self.dir.join("snapshot.json");
        let tmp_path = self.dir.join("snapshot.json.tmp");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp_path)?;
            f.write_all(state.to_string().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp_path, &snap_path)?;
        self.wal.reset()?;
        Ok(())
    }

    /// WAL statistics (for metrics / compaction policy).
    pub fn wal_stats(&self) -> WalStats {
        self.wal.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn rec(tag: &str, n: i64) -> Record {
        let mut o = Value::obj();
        o.set("n", n);
        Record::new(tag, Value::Obj(o))
    }

    #[test]
    fn empty_storage_loads_empty() {
        let d = TempDir::new("store-empty");
        let mut s = Storage::open(d.path()).unwrap();
        let (snap, events) = s.load().unwrap();
        assert!(snap.is_none());
        assert!(events.is_empty());
    }

    #[test]
    fn append_and_reload() {
        let d = TempDir::new("store-append");
        {
            let mut s = Storage::open(d.path()).unwrap();
            for i in 0..10 {
                s.append(&rec("e", i)).unwrap();
            }
        }
        let mut s = Storage::open(d.path()).unwrap();
        let (_, events) = s.load().unwrap();
        assert_eq!(events.len(), 10);
        assert_eq!(events[3], rec("e", 3));
    }

    #[test]
    fn compact_then_more_events() {
        let d = TempDir::new("store-compact");
        {
            let mut s = Storage::open(d.path()).unwrap();
            for i in 0..5 {
                s.append(&rec("pre", i)).unwrap();
            }
            let mut state = Value::obj();
            state.set("count", 5);
            s.compact(&Value::Obj(state)).unwrap();
            s.append(&rec("post", 100)).unwrap();
        }
        let mut s = Storage::open(d.path()).unwrap();
        let (snap, events) = s.load().unwrap();
        assert_eq!(snap.unwrap().get("count").as_i64(), Some(5));
        assert_eq!(events, vec![rec("post", 100)]);
    }

    #[test]
    fn record_roundtrip() {
        let r = rec("trial_tell", 42);
        assert_eq!(Record::from_value(&r.to_value()), Some(r));
    }

    #[test]
    fn record_commit_metadata_roundtrips_but_is_not_identity() {
        let mut r = rec("trial_tell", 7).with_shard(3);
        r.seq = 99;
        let back = Record::from_value(&r.to_value()).unwrap();
        assert_eq!(back.seq, 99);
        assert_eq!(back.shard, 3);
        // Equality ignores commit metadata.
        assert_eq!(back, rec("trial_tell", 7));
        // Pre-group-commit wire form (no "s"/"h") defaults to 0.
        let legacy = rec("trial_tell", 7);
        let mut v = Value::obj();
        v.set("t", "trial_tell").set("p", legacy.payload.clone());
        let parsed = Record::from_value(&Value::Obj(v)).unwrap();
        assert_eq!(parsed.seq, 0);
        assert_eq!(parsed.shard, 0);
    }
}

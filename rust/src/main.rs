//! `hopaas` — the HOPAAS service launcher and utility CLI.
//!
//! Subcommands:
//!   serve      run the coordination server (Table 1 APIs + dashboard)
//!   token      issue an API token against a secret (offline)
//!   campaign   run a simulated multi-site optimization campaign
//!   demo       one-node end-to-end demo against an in-process server
//!   bench-objective   evaluate a benchmark objective at a point
//!
//! Examples:
//!   hopaas serve --addr 0.0.0.0:8021 --data-dir ./hopaas-data
//!   hopaas serve --no-auth --workers 16
//!   hopaas token --secret hopaas-dev-secret --user alice --ttl 86400
//!   hopaas campaign --nodes 24 --trials 200 --objective rastrigin

use hopaas::config::{server_config, Args};
use hopaas::coordinator::auth::TokenService;
use hopaas::coordinator::service::{HopaasConfig, HopaasServer};
use hopaas::objectives::Objective;
use hopaas::worker::Campaign;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let code = match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "token" => cmd_token(&args),
        "campaign" => cmd_campaign(&args),
        "demo" => cmd_demo(&args),
        "export" => cmd_export(&args),
        "bench-objective" => cmd_bench_objective(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
hopaas — Hyperparameter Optimization as a Service (rust reproduction)

USAGE: hopaas <command> [flags]

COMMANDS:
  serve             run the HOPAAS server
                    --addr HOST:PORT   (default 127.0.0.1:8021)
                    --http-workers N   HTTP worker threads (default 128;
                                       --workers is the legacy alias)
                    --http-backlog N   queued connections before shedding 503
                    --data-dir PATH    durable WAL+snapshot storage
                    --no-auth          disable token auth (dev only)
                    --secret S         HMAC token secret
                    --shards N         engine shards (default 8)
                    --wal-batch N      fixed records per group-commit fsync
                                       (overrides the adaptive default)
                    --wal-batch-adaptive  adapt batch size up to the cap
                    --replay-threads N parallel recovery partitions (0 = per shard)
                    --lease-timeout S  worker heartbeat lease seconds
                                       (default 60; 0 disables leases)
                    --site-quota N     default max concurrent trials per site
                    --site-quota-map site=N,...  per-site overrides (0 = off)
                    --study-quota N    max concurrent trials per study (0 = off)
                    --tenant-quota N   default max concurrent trials per tenant
                                       (the auth token's user; 0 = off)
                    --tenant-quota-map user=N,...  per-tenant overrides
                    --tenant-ask-rate N  worker-less asks per tenant inside the
                                       sliding window (0 = off)
                    --tenant-ask-window S  ask-rate window seconds (default 60)
                    --compact-threads N  segment-cut side threads
                                       (0 = min(shards, cores); 1 = sequential)
                    --fairness-horizon S  fair-share waiting-mark lifetime /
                                       affinity grace (default 30)
                    --site-affinity    hand requeued trials to healthier sites
                    --requeue-max N    requeues before a preempted trial fails
                    --dead-worker-keep N  retired workers kept by the fleet GC
                    --site-idle-retention S  idle-site eviction window
                    --sampler-cache on|off  reuse a study's sampler fit across
                                       asks until a tell lands (default on;
                                       off refits every ask — same suggestions,
                                       debugging escape hatch)
                    --events-poll-timeout S  max long-poll park time for
                                       GET /api/studies/{id}/events (default 25)
                    --trace-capacity N retained request traces in the ring
                                       buffer (default 2048; 0 disables tracing)
                    --trace-sample P   fraction of requests whose trace is
                                       retained (default 1.0; slow ops always)
                    --trace-slow-ms MS requests at least this slow are always
                                       retained + logged (default 250; 0 = off)
                    --log-json         one structured JSON log line per
                                       retained request, on stderr
                    --role primary|follower  replication role (default
                                       primary; a follower serves reads
                                       only until POST /api/repl/promote)
                    --primary-url URL  the primary a follower bootstraps
                                       from and streams the WAL of
                    --repl-buffer N    acknowledged records retained for
                                       followers to fetch (default 65536)
                    --repl-poll-timeout S  replication long-poll window
                                       (default 2)
                    --config FILE      JSON config (flags override)
  token             mint an API token offline
                    --secret S --user NAME --ttl SECONDS
  campaign          simulated multi-site campaign against a fresh server
                    --nodes N --trials N --objective NAME --sampler NAME
                    --pruner NAME|none --steps N
                    --fleet            register workers + heartbeat leases
                    --ask-batch N      trials fetched per ask round trip
                    --viewers K        dashboard readers paging studies/trials
                                       and long-polling the event feed while
                                       the campaign runs
  demo              quick end-to-end demo (ask/should_prune/tell loop)
  export            dump a durable server's trials as CSV (offline)
                    --data-dir PATH [--study ID]
  bench-objective   --objective NAME --at x0,x1,...
";

fn cmd_serve(args: &Args) -> i32 {
    let (addr, config) = match server_config(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let reap_every = config
        .engine
        .reap_after
        .map(|_| std::time::Duration::from_secs(30));
    let follower = config.engine.follower;
    match HopaasServer::start(&addr, config) {
        Ok(server) => {
            let role = if follower { "follower (read-only)" } else { "primary" };
            println!(
                "hopaas {} serving on http://{} as {role}",
                hopaas::VERSION,
                server.addr()
            );
            let rec = server.engine.recovery_stats();
            if rec.recovered_records > 0 || rec.segments > 0 || rec.truncated_records > 0 {
                println!(
                    "recovery: {} record(s) replayed over {} segment(s), {} torn tail(s) truncated ({} bytes)",
                    rec.recovered_records, rec.segments, rec.truncated_records, rec.truncated_bytes
                );
            }
            println!("dashboard: http://{}/", server.addr());
            println!("bootstrap token: {}", server.bootstrap_token);
            // Maintenance loop: lease expiry every tick (workers of
            // vanished nodes requeue their trials within seconds), the
            // legacy reaper every `reap_every` for worker-less clients.
            let tick = std::time::Duration::from_secs(5);
            let reap_every = reap_every.unwrap_or(std::time::Duration::from_secs(3600));
            let mut since_reap = std::time::Duration::ZERO;
            loop {
                std::thread::sleep(tick);
                let requeued = server.engine.expire_leases();
                if requeued > 0 {
                    println!("lease expiry requeued {requeued} trial(s)");
                }
                since_reap += tick;
                if since_reap >= reap_every {
                    since_reap = std::time::Duration::ZERO;
                    let reaped = server.engine.reap_stale();
                    if reaped > 0 {
                        println!("reaped {reaped} stale trial(s)");
                    }
                }
            }
        }
        Err(e) => {
            eprintln!("failed to start: {e}");
            1
        }
    }
}

fn cmd_token(args: &Args) -> i32 {
    let secret = args.get_or("secret", "hopaas-dev-secret");
    let user = args.get_or("user", "anonymous");
    let ttl = args.get_f64("ttl", 86400.0);
    let svc = TokenService::new(secret.as_bytes());
    println!("{}", svc.issue(user, 0.0, ttl));
    0
}

fn cmd_campaign(args: &Args) -> i32 {
    let objective = match Objective::by_name(args.get_or("objective", "rastrigin")) {
        Some(o) => o,
        None => {
            eprintln!(
                "unknown objective; options: {:?}",
                hopaas::objectives::ALL.map(|o| o.name())
            );
            return 2;
        }
    };
    let server = match HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server: {e}");
            return 1;
        }
    };
    let mut campaign = Campaign::new(server.addr(), "x".into(), objective);
    campaign.n_nodes = args.get_u64("nodes", 24) as usize;
    campaign.max_trials = args.get_u64("trials", 200);
    campaign.steps_per_trial = args.get_u64("steps", 20);
    campaign.fleet = args.get_bool("fleet");
    campaign.ask_batch = args.get_u64("ask-batch", 1).max(1) as usize;
    campaign.viewers = args.get_u64("viewers", 0) as usize;
    // With the fleet protocol on, drive lease expiry while the
    // campaign runs (the role the serve loop plays in production).
    let pump_stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let pump = if campaign.fleet {
        let engine = server.engine.clone();
        let stop = pump_stop.clone();
        Some(std::thread::spawn(move || {
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                engine.expire_leases();
                std::thread::sleep(std::time::Duration::from_millis(200));
            }
        }))
    } else {
        None
    };
    campaign.sampler = match args.get_or("sampler", "tpe") {
        "random" => "random",
        "gp" => "gp",
        "cmaes" => "cmaes",
        "qmc" => "qmc",
        "grid" => "grid",
        _ => "tpe",
    };
    campaign.pruner = match args.get_or("pruner", "median") {
        "none" => None,
        "sha" => Some("sha"),
        "hyperband" => Some("hyperband"),
        "percentile" => Some("percentile"),
        _ => Some("median"),
    };
    println!(
        "campaign: {} nodes, {} trials, sampler={}, pruner={:?}, objective={}",
        campaign.n_nodes,
        campaign.max_trials,
        campaign.sampler,
        campaign.pruner,
        objective.name()
    );
    let result = campaign.run();
    pump_stop.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(h) = pump {
        let _ = h.join();
    }
    match result {
        Ok(report) => {
            println!(
                "completed={} pruned={} preempted={} requeued_taken={} steps={} best={:.5} wall={:.2}s ({:.1} trials/s)",
                report.completed,
                report.pruned,
                report.preempted,
                report.requeued_taken,
                report.steps_executed,
                report.best.unwrap_or(f64::NAN),
                report.wall.as_secs_f64(),
                report.throughput()
            );
            for (site, n) in &report.by_site {
                println!("  {site:>16}: {n} completed");
            }
            if campaign.viewers > 0 {
                println!("  viewers read {} page(s)", report.viewer_pages);
            }
            server.stop();
            0
        }
        Err(e) => {
            eprintln!("campaign failed: {e}");
            1
        }
    }
}

fn cmd_demo(_args: &Args) -> i32 {
    let server = HopaasServer::start(
        "127.0.0.1:0",
        HopaasConfig { auth_required: false, ..Default::default() },
    )
    .expect("server");
    println!("demo server on {}", server.addr());
    let mut client =
        hopaas::worker::HopaasClient::connect(server.addr(), "demo".into()).expect("client");
    let spec = hopaas::worker::StudySpec::new("demo-branin")
        .properties_json(Objective::Branin.properties())
        .sampler("tpe")
        .pruner("median");
    let mut best = f64::INFINITY;
    for i in 0..50 {
        let trial = client.ask(&spec).expect("ask");
        let v = Objective::Branin.eval_params(&trial.params);
        let mut pruned = false;
        for step in 1..=5 {
            let interim = v * (1.0 + 2.0 / step as f64);
            if client
                .should_prune(&trial, step, interim)
                .expect("should_prune")
            {
                pruned = true;
                break;
            }
        }
        if !pruned {
            client.tell(&trial, v).expect("tell");
            if v < best {
                best = v;
                println!("trial {i:>3}: new best {best:.5}");
            }
        }
    }
    println!("best after 50 trials: {best:.5} (f* = 0.39789)");
    server.stop();
    0
}

/// Offline CSV export of a durable server's trials — the analysis path
/// a campaign owner uses after the fact (no server required).
fn cmd_export(args: &Args) -> i32 {
    let Some(dir) = args.get("data-dir") else {
        eprintln!("export requires --data-dir");
        return 2;
    };
    let engine = match hopaas::coordinator::engine::Engine::open(
        dir,
        hopaas::coordinator::engine::EngineConfig::default(),
    ) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("open {dir}: {e}");
            return 1;
        }
    };
    let studies = engine.studies_json();
    let filter: Option<u64> = args.get("study").and_then(|s| s.parse().ok());
    println!("study_id,study_name,trial_id,number,state,value,values,node,params");
    for s in studies.as_arr().unwrap_or(&[]) {
        let sid = s.get("id").as_u64().unwrap_or(0);
        if filter.is_some_and(|f| f != sid) {
            continue;
        }
        let name = s.get("name").as_str().unwrap_or("");
        if let Some(trials) = engine.trials_json(sid) {
            for t in trials.as_arr().unwrap_or(&[]) {
                let csv_quote = |v: &hopaas::json::Value| {
                    format!("\"{}\"", v.to_string().replace('"', "\"\""))
                };
                println!(
                    "{sid},{name},{},{},{},{},{},{},{}",
                    t.get("id"),
                    t.get("number"),
                    t.get("state").as_str().unwrap_or(""),
                    t.get("value"),
                    csv_quote(t.get("values")),
                    t.get("node").as_str().unwrap_or(""),
                    csv_quote(t.get("params")),
                );
            }
        }
    }
    0
}

fn cmd_bench_objective(args: &Args) -> i32 {
    let objective = match Objective::by_name(args.get_or("objective", "sphere")) {
        Some(o) => o,
        None => {
            eprintln!("unknown objective");
            return 2;
        }
    };
    let x: Vec<f64> = args
        .get_or("at", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .filter_map(|s| s.parse().ok())
        .collect();
    if x.len() != objective.dim() {
        eprintln!("--at needs {} comma-separated values", objective.dim());
        return 2;
    }
    println!("{}({:?}) = {}", objective.name(), x, objective.eval(&x));
    0
}

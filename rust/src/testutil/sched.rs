//! Deterministic interleaving checker: a shuttle-style controlled
//! scheduler for small concurrency models.
//!
//! This is the dynamic half of the PR-10 concurrency tooling (the
//! static half is `crate::analysis`, the lock-hierarchy lint). Models
//! are miniatures of the repo's real protocols — WAL publish-before-ack,
//! epoch-guarded fit-cache write-back, view publication, promote-once,
//! scheduler slot release; see [`crate::testutil::models`] — written
//! against a cooperative scheduler:
//!
//! * each model thread is a real OS thread, but only **one runs at a
//!   time**: every interesting step is bracketed by a
//!   [`Sched::point`] / [`Sched::acquire`] yield point, and the
//!   explorer decides which blocked thread advances next;
//! * the sequence of decisions fully determines the execution, so a
//!   failing interleaving is **named** (a hash of its choice string)
//!   and can be [`replay`]ed exactly;
//! * exploration is **exhaustive DFS** over all interleavings up to
//!   [`Options::max_execs`] executions, then falls back to seeded
//!   random sampling (SplitMix64) — same options, same seed, same
//!   result, byte for byte;
//! * model mutexes are scheduler-aware: a thread whose next step is
//!   [`Sched::acquire`] on a held lock is simply *not enabled*, and if
//!   no thread is enabled while some are blocked the explorer reports a
//!   **deadlock** with the trace that produced it.
//!
//! Shared model state lives in [`MCell`]s. Because at most one model
//! thread runs between yield points, an `MCell` access is a single
//! atomic step of the model: races must be *modeled* by splitting them
//! across yield points (that is the point of the buggy variants).

use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// SplitMix64 — the same tiny seeded generator used by `bench`; good
/// enough to diversify schedules and trivially reproducible.
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h = (h ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Stable, human-quotable name for an interleaving: a hash of its
/// decision string. Two runs that made the same choices get the same
/// name; a failure report quotes it and [`replay`] reproduces it.
pub fn interleaving_name(choices: &[usize]) -> String {
    let mut bytes = Vec::with_capacity(choices.len());
    for &c in choices {
        bytes.push(c as u8);
        bytes.push(0xfe);
    }
    format!("ilv-{:08x}", fnv1a_bytes(&bytes) as u32)
}

/// Shared model state: a cell only ever touched by the single running
/// model thread, so every access is one atomic model step.
pub struct MCell<T>(Arc<Mutex<T>>);

impl<T> Clone for MCell<T> {
    fn clone(&self) -> Self {
        MCell(self.0.clone())
    }
}

impl<T> MCell<T> {
    pub fn new(v: T) -> Self {
        MCell(Arc::new(Mutex::new(v)))
    }

    /// Read-modify-write as one atomic model step.
    pub fn with<R>(&self, f: impl FnOnce(&mut T) -> R) -> R {
        f(&mut self.0.lock().unwrap_or_else(|p| p.into_inner()))
    }

    pub fn set(&self, v: T) {
        self.with(|s| *s = v);
    }
}

impl<T: Clone> MCell<T> {
    pub fn get(&self) -> T {
        self.with(|s| s.clone())
    }
}

/// What a blocked thread is waiting to do next.
#[derive(Clone, Copy)]
enum Pending {
    /// Plain yield point — always enabled.
    Step,
    /// Wants model lock `id` — enabled iff the lock is free.
    Lock(usize),
}

enum TState {
    /// Between yield points (or not yet at its first one).
    Running,
    Blocked(Pending, &'static str),
    Done,
}

struct Ctl {
    states: Vec<TState>,
    locks: Vec<bool>,
    abort: bool,
    panicked: Option<String>,
}

struct Controller {
    m: Mutex<Ctl>,
    cv: Condvar,
}

/// Sentinel unwound through blocked threads when the explorer aborts a
/// run after detecting a failure (so their OS threads exit cleanly).
struct AbortToken;

impl Controller {
    fn new(n_threads: usize, n_locks: usize) -> Controller {
        Controller {
            m: Mutex::new(Ctl {
                states: (0..n_threads).map(|_| TState::Running).collect(),
                locks: vec![false; n_locks],
                abort: false,
                panicked: None,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Ctl> {
        self.m.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Model-thread side: park at a yield point until scheduled.
    fn block(&self, tid: usize, pending: Pending, label: &'static str) {
        let mut g = self.lock();
        g.states[tid] = TState::Blocked(pending, label);
        self.cv.notify_all();
        loop {
            if g.abort {
                drop(g);
                panic::panic_any(AbortToken);
            }
            if matches!(g.states[tid], TState::Running) {
                return;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn release_lock(&self, id: usize) {
        let mut g = self.lock();
        debug_assert!(g.locks[id], "releasing a lock that is not held");
        g.locks[id] = false;
    }

    fn finish_thread(&self, tid: usize, payload: Option<Box<dyn std::any::Any + Send>>) {
        let mut g = self.lock();
        g.states[tid] = TState::Done;
        if let Some(p) = payload {
            if p.downcast_ref::<AbortToken>().is_none() && g.panicked.is_none() {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "model thread panicked".into());
                g.panicked = Some(msg);
            }
        }
        self.cv.notify_all();
    }

    fn abort_run(&self) {
        let mut g = self.lock();
        g.abort = true;
        self.cv.notify_all();
    }

    /// Explorer side: wait until no thread is between yield points,
    /// then report what can happen next.
    fn await_quiescent(&self) -> Quiescent {
        let mut g = self.lock();
        loop {
            if g.states.iter().any(|s| matches!(s, TState::Running)) {
                g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
                continue;
            }
            if let Some(msg) = g.panicked.take() {
                return Quiescent::Panicked(msg);
            }
            if g.states.iter().all(|s| matches!(s, TState::Done)) {
                return Quiescent::AllDone;
            }
            let enabled: Vec<usize> = g
                .states
                .iter()
                .enumerate()
                .filter_map(|(tid, s)| match s {
                    TState::Blocked(Pending::Step, _) => Some(tid),
                    TState::Blocked(Pending::Lock(l), _) if !g.locks[*l] => Some(tid),
                    _ => None,
                })
                .collect();
            return Quiescent::Choice(enabled);
        }
    }

    /// Explorer side: wake thread `tid`, granting its lock if it was
    /// waiting on one. Returns the step label for the trace.
    fn schedule(&self, tid: usize) -> &'static str {
        let mut g = self.lock();
        let (pending, label) = match &g.states[tid] {
            TState::Blocked(pending, label) => (*pending, *label),
            _ => unreachable!("scheduled a thread that is not blocked"),
        };
        if let Pending::Lock(l) = pending {
            debug_assert!(!g.locks[l], "scheduled a thread onto a held lock");
            g.locks[l] = true;
        }
        g.states[tid] = TState::Running;
        self.cv.notify_all();
        label
    }
}

enum Quiescent {
    AllDone,
    Panicked(String),
    Choice(Vec<usize>),
}

/// Handle passed to every model thread; all coordination goes through it.
pub struct Sched {
    ctl: Arc<Controller>,
    tid: usize,
}

impl Sched {
    /// A plain yield point: everything before it has happened, and the
    /// explorer now decides who runs next.
    pub fn point(&self, label: &'static str) {
        self.ctl.block(self.tid, Pending::Step, label);
    }

    /// Acquire model lock `id`: blocks (is not *enabled*) until the
    /// lock is free **and** the explorer schedules this thread, which
    /// takes the lock atomically with the scheduling decision.
    pub fn acquire(&self, id: usize, label: &'static str) {
        self.ctl.block(self.tid, Pending::Lock(id), label);
    }

    /// Release model lock `id` (immediate; not a yield point).
    pub fn release(&self, id: usize) {
        self.ctl.release_lock(id);
    }
}

/// One concrete, freshly-built run of a model: its threads and the
/// end-of-run invariant check.
pub struct Instance {
    /// Number of model locks (ids `0..n_locks` valid in [`Sched::acquire`]).
    pub n_locks: usize,
    /// One closure per model thread.
    pub threads: Vec<Box<dyn FnOnce(&Sched) + Send>>,
    /// Invariant check, run after all threads finish cleanly.
    pub finish: Box<dyn FnOnce() -> Result<(), String>>,
}

/// How a run failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailureKind {
    /// Some threads blocked, none enabled.
    Deadlock,
    /// The end-of-run invariant check rejected the final state.
    Invariant(String),
    /// A model thread panicked mid-run.
    Panic(String),
}

/// A failing interleaving: its stable name, the decision string that
/// reproduces it, and the step trace `(thread, label)` it produced.
#[derive(Debug, Clone)]
pub struct Failure {
    pub name: String,
    pub kind: FailureKind,
    pub choices: Vec<usize>,
    pub trace: Vec<(usize, &'static str)>,
}

impl Failure {
    /// Render the trace one step per line, e.g. `t1:ring:publish`.
    pub fn render_trace(&self) -> String {
        self.trace
            .iter()
            .map(|(tid, label)| format!("t{tid}:{label}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Exploration result.
#[derive(Debug)]
pub struct Report {
    /// Executions actually run (DFS + random).
    pub execs: usize,
    /// True iff DFS enumerated *every* interleaving within budget.
    pub exhaustive: bool,
    /// First failure found, if any (exploration stops at the first).
    pub failure: Option<Failure>,
}

/// Exploration budget and seed.
#[derive(Clone, Copy)]
pub struct Options {
    /// DFS execution budget; small models finish exhaustively below it.
    pub max_execs: usize,
    /// Seeded-random executions to run if DFS did not finish.
    pub random_execs: usize,
    pub seed: u64,
    /// Per-run scheduler step budget (guards against unbounded models).
    pub max_steps: usize,
}

impl Default for Options {
    fn default() -> Options {
        Options { max_execs: 4096, random_execs: 2048, seed: 0xC0FFEE, max_steps: 512 }
    }
}

/// What one execution produced: the choices made, the enabled-count at
/// each step (the DFS branching record), the trace, and the failure.
struct RunOutcome {
    choices: Vec<usize>,
    counts: Vec<usize>,
    trace: Vec<(usize, &'static str)>,
    failure: Option<FailureKind>,
}

/// Run one execution under `decide` (given the step index and enabled
/// count, pick an index into the enabled set).
fn run_one(
    inst: Instance,
    max_steps: usize,
    decide: &mut dyn FnMut(usize, usize) -> usize,
) -> RunOutcome {
    let n = inst.threads.len();
    let ctl = Arc::new(Controller::new(n, inst.n_locks));
    let mut handles = Vec::with_capacity(n);
    for (tid, f) in inst.threads.into_iter().enumerate() {
        let c = ctl.clone();
        handles.push(thread::spawn(move || {
            let s = Sched { ctl: c.clone(), tid };
            // Every thread starts parked so nothing runs before the
            // explorer's first decision.
            let result = panic::catch_unwind(AssertUnwindSafe(|| {
                s.point("spawn");
                f(&s);
            }));
            c.finish_thread(tid, result.err());
        }));
    }

    let mut choices = Vec::new();
    let mut counts = Vec::new();
    let mut trace = Vec::new();
    let failure = loop {
        match ctl.await_quiescent() {
            Quiescent::AllDone => break None,
            Quiescent::Panicked(msg) => break Some(FailureKind::Panic(msg)),
            Quiescent::Choice(enabled) => {
                if enabled.is_empty() {
                    break Some(FailureKind::Deadlock);
                }
                if trace.len() >= max_steps {
                    break Some(FailureKind::Panic(format!(
                        "scheduler step budget ({max_steps}) exceeded — unbounded model?"
                    )));
                }
                let k = decide(choices.len(), enabled.len()).min(enabled.len() - 1);
                counts.push(enabled.len());
                choices.push(k);
                let tid = enabled[k];
                let label = ctl.schedule(tid);
                trace.push((tid, label));
            }
        }
    };
    if failure.is_some() {
        ctl.abort_run();
    }
    for h in handles {
        let _ = h.join();
    }
    let failure = match failure {
        Some(f) => Some(f),
        None => (inst.finish)().err().map(FailureKind::Invariant),
    };
    RunOutcome { choices, counts, trace, failure }
}

fn failure_from(
    kind: FailureKind,
    choices: Vec<usize>,
    trace: Vec<(usize, &'static str)>,
) -> Failure {
    Failure { name: interleaving_name(&choices), kind, choices, trace }
}

/// Explore a model: exhaustive DFS over interleavings up to the budget,
/// then seeded random sampling. Deterministic for fixed `opts`: the
/// same exploration order, the same report, every time. Stops at the
/// first failure.
pub fn explore(factory: &dyn Fn() -> Instance, opts: &Options) -> Report {
    let mut execs = 0usize;
    // DFS over decision strings: rerun with an incremented prefix until
    // the odometer rolls over.
    let mut prefix: Vec<usize> = Vec::new();
    loop {
        if execs >= opts.max_execs {
            break; // budget hit — fall through to random sampling
        }
        let run = run_one(factory(), opts.max_steps, &mut |step, n| {
            if step < prefix.len() {
                prefix[step].min(n - 1)
            } else {
                0
            }
        });
        execs += 1;
        if let Some(kind) = run.failure {
            return Report {
                execs,
                exhaustive: false,
                failure: Some(failure_from(kind, run.choices, run.trace)),
            };
        }
        // Next prefix: bump the rightmost choice that still has an
        // unexplored sibling; exhausted when none does.
        let mut i = run.choices.len();
        let next = loop {
            if i == 0 {
                break None;
            }
            i -= 1;
            if run.choices[i] + 1 < run.counts[i] {
                let mut p = run.choices[..i].to_vec();
                p.push(run.choices[i] + 1);
                break Some(p);
            }
        };
        match next {
            Some(p) => prefix = p,
            None => return Report { execs, exhaustive: true, failure: None },
        }
    }
    let mut rng = SplitMix64(opts.seed);
    for _ in 0..opts.random_execs {
        let run = run_one(factory(), opts.max_steps, &mut |_step, n| {
            (rng.next_u64() % n as u64) as usize
        });
        execs += 1;
        if let Some(kind) = run.failure {
            return Report {
                execs,
                exhaustive: false,
                failure: Some(failure_from(kind, run.choices, run.trace)),
            };
        }
    }
    Report { execs, exhaustive: false, failure: None }
}

/// Re-run a single interleaving from its decision string (as recorded
/// in [`Failure::choices`]). Returns the (possibly clean) outcome.
pub fn replay(factory: &dyn Fn() -> Instance, choices: &[usize], max_steps: usize) -> Report {
    let run = run_one(factory(), max_steps, &mut |step, n| {
        choices.get(step).copied().unwrap_or(0).min(n - 1)
    });
    Report {
        execs: 1,
        exhaustive: false,
        failure: run.failure.map(|kind| failure_from(kind, run.choices, run.trace)),
    }
}

//! Concurrency protocol miniatures for the interleaving checker.
//!
//! Each model distills one real coordination protocol from the
//! coordinator into a handful of scheduler steps, in two variants:
//! the **shipped** protocol (`buggy = false`), which must survive
//! exhaustive interleaving search, and a **planted bug** variant
//! (`buggy = true`) — the ordering mistake the protocol exists to
//! prevent — which the checker must find and name.
//!
//! The five models mirror, in order: the WAL group-commit
//! publish-before-ack contract against the replication ring's eviction
//! floor (`store/group.rs`); the tell-epoch guard on sampler fit-cache
//! write-back (`coordinator/engine.rs` CS2); snapshot-swap view
//! publication (`coordinator/views.rs`); promote-exactly-once on
//! follower failover (`coordinator/replica.rs`); and the fleet
//! scheduler's release-exactly-once slot accounting — the PR-4
//! double-release bug class (`fleet/scheduler.rs`).

use super::sched::{Instance, MCell};

/// A named model: a fresh [`Instance`] per exploration run.
pub struct Model {
    pub name: String,
    pub factory: Box<dyn Fn() -> Instance>,
}

/// All five protocol miniatures.
pub fn all(buggy: bool) -> Vec<Model> {
    vec![
        wal_publish_before_ack(buggy),
        fit_cache_epoch_guard(buggy),
        view_snapshot_swap(buggy),
        promote_once(buggy),
        slot_release_once(buggy),
    ]
}

/// WAL publish-before-ack vs the replication ring's eviction floor.
///
/// Contract (`store/group.rs`): a batch enters the replication ring
/// *before* its commit is acknowledged, and the ring never evicts
/// entries a follower has not fetched. Planted bug: ack before
/// publish — a follower that reacts to the ack can find the ring
/// missing the batch, a replication gap.
pub fn wal_publish_before_ack(buggy: bool) -> Model {
    let name = format!("wal_publish_before_ack{}", if buggy { "[buggy]" } else { "" });
    let factory = move || {
        let ring: MCell<Vec<u64>> = MCell::new(Vec::new());
        let acked: MCell<u64> = MCell::new(0);
        let fetched: MCell<u64> = MCell::new(0);
        let gap: MCell<bool> = MCell::new(false);

        let writer = {
            let (ring, acked) = (ring.clone(), acked.clone());
            Box::new(move |s: &super::sched::Sched| {
                // One batch keeps the model exhaustively explorable;
                // the race window is the same for every batch.
                let seq = 1u64;
                s.point("wal:append");
                if buggy {
                    // Planted bug: the client is acked before the
                    // batch is visible to followers.
                    s.point("ack");
                    acked.set(seq);
                    s.point("ring:publish");
                    ring.with(|r| r.push(seq));
                } else {
                    s.point("ring:publish");
                    ring.with(|r| r.push(seq));
                    s.point("ack");
                    acked.set(seq);
                }
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        let follower = {
            let (ring, acked, fetched, gap) =
                (ring.clone(), acked.clone(), fetched.clone(), gap.clone());
            Box::new(move |s: &super::sched::Sched| {
                for _ in 0..2 {
                    s.point("follower:fetch");
                    let high = acked.get();
                    let mut at = fetched.get();
                    while at < high {
                        at += 1;
                        if ring.with(|r| r.contains(&at)) {
                            fetched.set(at);
                        } else {
                            // An acked batch is neither fetched nor in
                            // the ring: replication gap.
                            gap.set(true);
                            return;
                        }
                    }
                }
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        let evictor = {
            let (ring, fetched) = (ring.clone(), fetched.clone());
            Box::new(move |s: &super::sched::Sched| {
                s.point("ring:evict");
                // Correct eviction floor: only below the fetch
                // watermark, never by ack.
                let floor = fetched.get();
                ring.with(|r| r.retain(|&seq| seq > floor));
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        Instance {
            n_locks: 0,
            threads: vec![writer, follower, evictor],
            finish: Box::new(move || {
                if gap.get() {
                    Err("follower observed an acked batch missing from the ring".into())
                } else {
                    Ok(())
                }
            }),
        }
    };
    Model { name, factory: Box::new(factory) }
}

/// Tell-epoch guard on sampler fit-cache write-back (CS2).
///
/// Contract (`coordinator/engine.rs`): a fit computed outside the
/// shard lock is written back only if the study's tell-epoch is
/// unchanged; a concurrent `tell` bumps the epoch and invalidates the
/// cache. Planted bug: unconditional write-back installs a fit for
/// data that no longer exists.
pub fn fit_cache_epoch_guard(buggy: bool) -> Model {
    let name = format!("fit_cache_epoch_guard{}", if buggy { "[buggy]" } else { "" });
    let factory = move || {
        let epoch: MCell<u64> = MCell::new(0);
        // Cache entry: (epoch the fit was computed at, fit payload).
        let cache: MCell<Option<(u64, u64)>> = MCell::new(None);

        let fitter = {
            let (epoch, cache) = (epoch.clone(), cache.clone());
            Box::new(move |s: &super::sched::Sched| {
                s.point("cs2:read-epoch");
                let e = epoch.get();
                s.point("cs2:fit");
                let fit = (e, e.wrapping_mul(10) + 7);
                s.point("cs2:write-back");
                if buggy {
                    // Planted bug: no epoch check on write-back.
                    cache.set(Some(fit));
                } else {
                    cache.with(|c| {
                        // (the epoch read and the store are one model
                        // step here: the real code does both under the
                        // shard lock)
                        if epoch.get() == e {
                            *c = Some(fit);
                        }
                    });
                }
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        let teller = {
            let (epoch, cache) = (epoch.clone(), cache.clone());
            Box::new(move |s: &super::sched::Sched| {
                s.point("tell:bump-epoch");
                epoch.with(|e| *e += 1);
                cache.set(None);
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        Instance {
            n_locks: 0,
            threads: vec![fitter, teller],
            finish: Box::new(move || match cache.get() {
                Some((fit_epoch, _)) if fit_epoch != epoch.get() => Err(format!(
                    "stale fit installed: cached epoch {fit_epoch}, current {}",
                    epoch.get()
                )),
                _ => Ok(()),
            }),
        }
    };
    Model { name, factory: Box::new(factory) }
}

/// Snapshot-swap view publication vs reader snapshots.
///
/// Contract (`coordinator/views.rs`): a view rebuild produces a fresh
/// immutable value and publishes it with a single pointer swap;
/// readers always see a complete view. Planted bug: mutating the
/// published view in place — a reader between the field writes sees a
/// torn view.
pub fn view_snapshot_swap(buggy: bool) -> Model {
    let name = format!("view_snapshot_swap{}", if buggy { "[buggy]" } else { "" });
    let factory = move || {
        // Published view: (version, checksum); coherent iff
        // checksum == version * 100.
        let slot: MCell<(u64, u64)> = MCell::new((0, 0));
        let torn: MCell<bool> = MCell::new(false);

        let builder = {
            let slot = slot.clone();
            Box::new(move |s: &super::sched::Sched| {
                for v in 1..=2u64 {
                    s.point("view:rebuild");
                    let fresh = (v, v * 100);
                    if buggy {
                        // Planted bug: in-place publication, field by
                        // field, across a yield point.
                        s.point("view:write-version");
                        slot.with(|view| view.0 = fresh.0);
                        s.point("view:write-checksum");
                        slot.with(|view| view.1 = fresh.1);
                    } else {
                        s.point("view:swap");
                        slot.set(fresh);
                    }
                }
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        let reader = {
            let (slot, torn) = (slot.clone(), torn.clone());
            Box::new(move |s: &super::sched::Sched| {
                for _ in 0..2 {
                    s.point("read:snapshot");
                    let (v, sum) = slot.get();
                    if sum != v * 100 {
                        torn.set(true);
                    }
                }
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        Instance {
            n_locks: 0,
            threads: vec![builder, reader],
            finish: Box::new(move || {
                if torn.get() {
                    Err("reader observed a torn view snapshot".into())
                } else {
                    Ok(())
                }
            }),
        }
    };
    Model { name, factory: Box::new(factory) }
}

/// Promote-exactly-once on follower failover.
///
/// Contract (`coordinator/replica.rs`): when the primary dies, the
/// promotion path runs exactly once — the winner atomically claims the
/// flag, then drains and seals the applier. Planted bug: check and
/// claim as separate steps — two promoters both win and the applier is
/// drained twice.
pub fn promote_once(buggy: bool) -> Model {
    let name = format!("promote_once{}", if buggy { "[buggy]" } else { "" });
    let factory = move || {
        let promoted: MCell<bool> = MCell::new(false);
        let drains: MCell<u32> = MCell::new(0);

        let promoter = |promoted: MCell<bool>, drains: MCell<u32>| {
            Box::new(move |s: &super::sched::Sched| {
                let won = if buggy {
                    // Planted bug: test-then-set across a yield point.
                    s.point("promote:check");
                    let already = promoted.get();
                    s.point("promote:claim");
                    if !already {
                        promoted.set(true);
                    }
                    !already
                } else {
                    s.point("promote:cas");
                    promoted.with(|p| !std::mem::replace(p, true))
                };
                if won {
                    s.point("promote:drain-seal");
                    drains.with(|d| *d += 1);
                }
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        Instance {
            n_locks: 0,
            threads: vec![
                promoter(promoted.clone(), drains.clone()),
                promoter(promoted.clone(), drains.clone()),
            ],
            finish: Box::new(move || match drains.get() {
                1 => Ok(()),
                n => Err(format!("applier drained {n} times; promotion must run exactly once")),
            }),
        }
    };
    Model { name, factory: Box::new(factory) }
}

/// Release-exactly-once slot accounting (the PR-4 double-release bug).
///
/// Contract (`fleet/scheduler.rs`): a preempted trial's site slot is
/// released once, whichever of the lease-expiry reaper or the explicit
/// `fail` path gets there first — both guard on a per-trial released
/// flag *atomically with* the decrement, under the fleet lock. Planted
/// bug (shipped before PR 4 fixed it): flag check and slot decrement
/// as separate steps — both paths pass the check and the site's used
/// count goes negative, inflating capacity for every later admission.
pub fn slot_release_once(buggy: bool) -> Model {
    let name = format!("slot_release_once{}", if buggy { "[buggy]" } else { "" });
    const FLEET_LOCK: usize = 0;
    let factory = move || {
        let released: MCell<bool> = MCell::new(false);
        let used: MCell<i64> = MCell::new(1); // one admitted trial

        let releaser = |path: &'static str, released: MCell<bool>, used: MCell<i64>| {
            let (enter, dec): (&'static str, &'static str) = match path {
                "reaper" => ("reaper:lock", "reaper:release"),
                _ => ("fail:lock", "fail:release"),
            };
            Box::new(move |s: &super::sched::Sched| {
                if buggy {
                    // Planted bug: check under one lock acquisition,
                    // decrement under another.
                    s.acquire(FLEET_LOCK, enter);
                    let already = released.get();
                    s.release(FLEET_LOCK);
                    if !already {
                        s.acquire(FLEET_LOCK, dec);
                        released.set(true);
                        used.with(|u| *u -= 1);
                        s.release(FLEET_LOCK);
                    }
                } else {
                    s.acquire(FLEET_LOCK, enter);
                    if !released.get() {
                        released.set(true);
                        used.with(|u| *u -= 1);
                    }
                    s.release(FLEET_LOCK);
                }
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };

        Instance {
            n_locks: 1,
            threads: vec![
                releaser("reaper", released.clone(), used.clone()),
                releaser("fail", released.clone(), used.clone()),
            ],
            finish: Box::new(move || match used.get() {
                0 => Ok(()),
                n => Err(format!("slot accounting off: used = {n} (double release)")),
            }),
        }
    };
    Model { name, factory: Box::new(factory) }
}

/// Bonus self-test model (not part of [`all`]): two threads taking two
/// locks — in the same order (`buggy = false`) or opposite orders
/// (`buggy = true`). The buggy variant is the classic AB/BA deadlock
/// the lock-hierarchy lint exists to prevent; the checker must find it
/// as a [`super::sched::FailureKind::Deadlock`].
pub fn lock_order_demo(buggy: bool) -> Model {
    let name = format!("lock_order_demo{}", if buggy { "[buggy]" } else { "" });
    const A: usize = 0;
    const B: usize = 1;
    let factory = move || {
        let taker = |first: usize, second: usize| {
            Box::new(move |s: &super::sched::Sched| {
                s.acquire(first, if first == A { "lock:A" } else { "lock:B" });
                s.point("critical");
                s.acquire(second, if second == A { "lock:A" } else { "lock:B" });
                s.release(second);
                s.release(first);
            }) as Box<dyn FnOnce(&super::sched::Sched) + Send>
        };
        let (t1_first, t1_second) = (A, B);
        let (t2_first, t2_second) = if buggy { (B, A) } else { (A, B) };
        Instance {
            n_locks: 2,
            threads: vec![taker(t1_first, t1_second), taker(t2_first, t2_second)],
            finish: Box::new(|| Ok(())),
        }
    };
    Model { name, factory: Box::new(factory) }
}

//! Deterministic crash-injection harness.
//!
//! The store consults a [`FaultHook`](crate::store::FaultHook) at named
//! kill-points (`"rotate"`, `"segment.write"`, `"manifest.rename"`,
//! `"gc"`, …). [`KillSwitch`] implements that hook for tests: arm it at
//! a point (optionally "the Nth time the point is reached"), run the
//! workload, and the storage dies at exactly that instant — the current
//! operation fails and every later one errors, which is what a power
//! cut leaves behind. The hook and its hit counters are fully
//! thread-safe: with parallel compaction the `segment.*` points fire on
//! *pool* threads, racing each other, and the first firing kills every
//! storage handle at once (the shared `killed` flag), exactly like one
//! power cut takes out every thread of a real process. The test then
//! reopens the directory with a fresh, unhooked engine and asserts the
//! two crash invariants:
//!
//! * **acknowledged ⇒ durable** — every mutation acknowledged before
//!   the kill is present after recovery;
//! * **replay idempotence** — nothing is applied twice, whatever
//!   half-finished compaction artifacts the kill left on disk.
//!
//! `tests/crash_injection.rs` drives every compaction kill-point
//! through this harness.

use crate::store::FaultHook;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// One armed kill-point. Create with [`KillSwitch::new`], convert with
/// [`KillSwitch::hook`], hand the hook to
/// [`Storage::open_with_hook`](crate::store::Storage::open_with_hook).
pub struct KillSwitch {
    /// `(point, skip)`: fire when `point` is hit for the `skip+1`-th time.
    target: Mutex<Option<(String, usize)>>,
    hits: AtomicUsize,
    fired: AtomicBool,
}

impl KillSwitch {
    /// A disarmed switch (hook passes every point through).
    pub fn new() -> Arc<KillSwitch> {
        Arc::new(KillSwitch {
            target: Mutex::new(None),
            hits: AtomicUsize::new(0),
            fired: AtomicBool::new(false),
        })
    }

    /// Arm at the first occurrence of `point`.
    pub fn arm(self: &Arc<Self>, point: &str) -> Arc<Self> {
        self.arm_nth(point, 0)
    }

    /// Arm at the `(skip+1)`-th occurrence of `point` — e.g.
    /// `arm_nth("segment.write", 2)` kills while the third shard's
    /// segment is being cut.
    pub fn arm_nth(self: &Arc<Self>, point: &str, skip: usize) -> Arc<Self> {
        *self.target.lock().unwrap() = Some((point.to_string(), skip));
        self.hits.store(0, Ordering::SeqCst);
        self.fired.store(false, Ordering::SeqCst);
        self.clone()
    }

    /// Did the armed kill-point fire? Tests assert this to prove the
    /// workload actually reached the point they meant to crash at.
    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }

    /// The [`FaultHook`] to plant into a `Storage`.
    pub fn hook(self: &Arc<Self>) -> FaultHook {
        let this = self.clone();
        Arc::new(move |point: &str| {
            let guard = this.target.lock().unwrap();
            let Some((target, skip)) = guard.as_ref() else { return false };
            if target != point {
                return false;
            }
            let hit = this.hits.fetch_add(1, Ordering::SeqCst);
            if hit == *skip {
                this.fired.store(true, Ordering::SeqCst);
                true
            } else {
                false
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_on_nth_occurrence_only() {
        let ks = KillSwitch::new();
        let hook = ks.arm_nth("segment.write", 2).hook();
        assert!(!hook("rotate"));
        assert!(!hook("segment.write"), "first hit skipped");
        assert!(!hook("segment.write"), "second hit skipped");
        assert!(!ks.fired());
        assert!(hook("segment.write"), "third hit fires");
        assert!(ks.fired());
        // Past occurrences don't re-fire (the storage is dead anyway).
        assert!(!hook("segment.write"));
    }

    #[test]
    fn disarmed_switch_passes_everything() {
        let ks = KillSwitch::new();
        let hook = ks.hook();
        for p in ["append", "sync", "manifest.rename", "gc"] {
            assert!(!hook(p));
        }
        assert!(!ks.fired());
    }

    #[test]
    fn rearming_resets_counters() {
        let ks = KillSwitch::new();
        let hook = ks.arm("gc").hook();
        assert!(hook("gc"));
        assert!(ks.fired());
        ks.arm_nth("rotate", 1);
        assert!(!ks.fired(), "rearm clears fired");
        assert!(!hook("rotate"));
        assert!(hook("rotate"));
        assert!(ks.fired());
    }
}

//! Minimal property-based testing harness with shrinking.
//!
//! Usage:
//! ```ignore
//! prop::check(256, |g| {
//!     let xs = g.vec(0..=32, |g| g.i64(-100, 100));
//!     let mut sorted = xs.clone();
//!     sorted.sort();
//!     prop::assert_holds(sorted.len() == xs.len(), format!("len {:?}", xs))
//! });
//! ```
//!
//! On failure the harness re-runs the property with progressively simpler
//! "sizes" (the generator scales collection lengths and magnitudes by the
//! current size), reporting the smallest failing seed it finds. Shrinking
//! is stochastic rather than structural — simpler than proptest but
//! sufficient to reduce most failures to small cases, and fully
//! deterministic from the printed seed.

use crate::rng::Rng;

/// Generator handle passed to properties.
pub struct Gen {
    rng: Rng,
    /// Size scaling in (0, 1]; shrinking lowers this.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// Raw RNG access.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in `[lo, hi]`, magnitude scaled by current size.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        let span = ((hi - lo) as f64 * self.size).ceil().max(1.0) as i64;
        let hi2 = (lo + span).min(hi);
        self.rng.int_range(lo, hi2)
    }

    /// usize in `[lo, hi]`, scaled by size.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    /// f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Finite f64 covering positives, negatives, zeros and extremes
    /// (bounded by size).
    pub fn f64_any(&mut self) -> f64 {
        let mag = 10f64.powf(self.rng.uniform(-6.0, 6.0 * self.size));
        let sign = if self.rng.chance(0.5) { 1.0 } else { -1.0 };
        if self.rng.chance(0.05) {
            0.0
        } else {
            sign * mag
        }
    }

    /// Boolean.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector with length in `len` range, elements from `f`.
    pub fn vec<T>(&mut self, len: std::ops::RangeInclusive<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(*len.start(), *len.end());
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// ASCII identifier-ish string (for names, keys).
    pub fn ident(&mut self, max_len: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_-";
        let n = self.usize(1, max_len.max(1));
        (0..n)
            .map(|_| CHARS[self.rng.below(CHARS.len() as u64) as usize] as char)
            .collect()
    }

    /// Arbitrary unicode-ish string including escapes-relevant chars.
    pub fn string(&mut self, max_len: usize) -> String {
        let n = self.usize(0, max_len);
        (0..n)
            .map(|_| {
                match self.rng.below(8) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => '\u{1F600}',
                    4 => 'é',
                    5 => '\t',
                    _ => (b'a' + self.rng.below(26) as u8) as char,
                }
            })
            .collect()
    }
}

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Convenience: turn a boolean + message into a `PropResult`.
pub fn assert_holds(ok: bool, msg: impl Into<String>) -> PropResult {
    if ok {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`. Panics with the seed and (shrunk)
/// message on failure. Seed base is derived from the property's code
/// location via `#[track_caller]` so different call sites explore
/// different streams but each is reproducible.
#[track_caller]
pub fn check(cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    let loc = std::panic::Location::caller();
    let base = crate::rng::mix(loc.line() as u64, loc.file().len() as u64);
    check_seeded(base, cases, prop)
}

/// As [`check`] but with an explicit seed base.
pub fn check_seeded(base: u64, cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let seed = crate::rng::mix(base, case);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry with smaller sizes / derived seeds, keep the
            // failure with the smallest size.
            let mut best: (f64, u64, String) = (1.0, seed, msg);
            for shrink_round in 0..200u64 {
                let size = 0.02 + 0.98 * (shrink_round as f64 % 10.0) / 10.0;
                if size >= best.0 {
                    continue;
                }
                let s2 = crate::rng::mix(seed, 1000 + shrink_round);
                let mut g2 = Gen::new(s2, size);
                if let Err(m2) = prop(&mut g2) {
                    best = (size, s2, m2);
                }
            }
            panic!(
                "property failed (seed={:#x}, size={:.2}): {}",
                best.1, best.0, best.2
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |g| {
            let v = g.vec(0..=16, |g| g.i64(-5, 5));
            assert_holds(v.len() <= 16, "len bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(64, |g| {
            let x = g.i64(0, 100);
            assert_holds(x < 90, format!("x={x}"))
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check(128, |g| {
            let x = g.i64(-3, 9);
            assert_holds((-3..=9).contains(&x), format!("x={x}"))
        });
    }
}

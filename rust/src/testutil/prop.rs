//! Minimal property-based testing harness with shrinking.
//!
//! Usage:
//! ```ignore
//! prop::check(256, |g| {
//!     let xs = g.vec(0..=32, |g| g.i64(-100, 100));
//!     let mut sorted = xs.clone();
//!     sorted.sort();
//!     prop::assert_holds(sorted.len() == xs.len(), format!("len {:?}", xs))
//! });
//! ```
//!
//! On failure the harness re-runs the property with progressively simpler
//! "sizes" (the generator scales collection lengths and magnitudes by the
//! current size), reporting the smallest failing seed it finds. Shrinking
//! is stochastic rather than structural — simpler than proptest but
//! sufficient to reduce most failures to small cases, and fully
//! deterministic from the printed seed.

use crate::rng::Rng;

/// Generator handle passed to properties.
pub struct Gen {
    rng: Rng,
    /// Size scaling in (0, 1]; shrinking lowers this.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// Raw RNG access.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in `[lo, hi]`, magnitude scaled by current size.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        let span = ((hi - lo) as f64 * self.size).ceil().max(1.0) as i64;
        let hi2 = (lo + span).min(hi);
        self.rng.int_range(lo, hi2)
    }

    /// usize in `[lo, hi]`, scaled by size.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    /// f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Finite f64 covering positives, negatives, zeros and extremes
    /// (bounded by size).
    pub fn f64_any(&mut self) -> f64 {
        let mag = 10f64.powf(self.rng.uniform(-6.0, 6.0 * self.size));
        let sign = if self.rng.chance(0.5) { 1.0 } else { -1.0 };
        if self.rng.chance(0.05) {
            0.0
        } else {
            sign * mag
        }
    }

    /// Boolean.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector with length in `len` range, elements from `f`.
    pub fn vec<T>(&mut self, len: std::ops::RangeInclusive<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(*len.start(), *len.end());
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// ASCII identifier-ish string (for names, keys).
    pub fn ident(&mut self, max_len: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_-";
        let n = self.usize(1, max_len.max(1));
        (0..n)
            .map(|_| CHARS[self.rng.below(CHARS.len() as u64) as usize] as char)
            .collect()
    }

    /// Arbitrary unicode-ish string including escapes-relevant chars.
    pub fn string(&mut self, max_len: usize) -> String {
        let n = self.usize(0, max_len);
        (0..n)
            .map(|_| {
                match self.rng.below(8) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => '\u{1F600}',
                    4 => 'é',
                    5 => '\t',
                    _ => (b'a' + self.rng.below(26) as u8) as char,
                }
            })
            .collect()
    }
}

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Convenience: turn a boolean + message into a `PropResult`.
pub fn assert_holds(ok: bool, msg: impl Into<String>) -> PropResult {
    if ok {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`. Panics with the seed and (shrunk)
/// message on failure. Seed base is derived from the property's code
/// location via `#[track_caller]` so different call sites explore
/// different streams but each is reproducible.
#[track_caller]
pub fn check(cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    let loc = std::panic::Location::caller();
    let base = crate::rng::mix(loc.line() as u64, loc.file().len() as u64);
    check_seeded(base, cases, prop)
}

/// As [`check`] but with an explicit seed base.
pub fn check_seeded(base: u64, cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let seed = crate::rng::mix(base, case);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry with smaller sizes / derived seeds, keep the
            // failure with the smallest size.
            let mut best: (f64, u64, String) = (1.0, seed, msg);
            for shrink_round in 0..200u64 {
                let size = 0.02 + 0.98 * (shrink_round as f64 % 10.0) / 10.0;
                if size >= best.0 {
                    continue;
                }
                let s2 = crate::rng::mix(seed, 1000 + shrink_round);
                let mut g2 = Gen::new(s2, size);
                if let Err(m2) = prop(&mut g2) {
                    best = (size, s2, m2);
                }
            }
            panic!(
                "property failed (seed={:#x}, size={:.2}): {}",
                best.1, best.0, best.2
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fuzz the recovery path end to end: a random interleaving of
    /// `ask`/`tell`/`should_prune` across several studies on a durable
    /// engine, then random byte-level log damage (truncation or a bit
    /// flip), then recovery on a possibly different shard count. The
    /// recovered state must be *prefix-consistent*:
    ///
    /// * completeness — every op whose bytes lie entirely before the
    ///   damage point is fully recovered;
    /// * prefix — op survival is monotone in commit order: once one op
    ///   is missing, every later op is missing too (no resurrection
    ///   past a gap);
    /// * no phantoms — every recovered trial/value was actually
    ///   acknowledged.
    #[test]
    fn prop_engine_recovery_is_prefix_consistent() {
        use crate::coordinator::engine::{Engine, EngineConfig};
        use crate::json::{parse, Value};
        use crate::testutil::TempDir;

        #[derive(Debug)]
        enum Op {
            /// Trial created: (trial_id, bytes_after).
            Ask(u64, u64),
            /// Trial told: (trial_id, value, bytes_after).
            Tell(u64, f64, u64),
        }

        fn ask_body(study: usize) -> Value {
            parse(&format!(
                r#"{{
                "study_name": "fuzz-{study}",
                "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
                "direction": "minimize",
                "sampler": {{"name": "random"}}
            }}"#
            ))
            .unwrap()
        }

        check(24, |g| {
            let shard_counts = [1usize, 4, 8];
            let writer_shards = *g.choose(&shard_counts);
            let reader_shards = *g.choose(&shard_counts);
            let d = TempDir::new("prop-recovery");
            let wal = d.path().join("wal.log");
            let n_studies = g.usize(1, 3);
            let n_ops = g.usize(1, 24);

            // Phase 1: random mutation interleaving, recording the log
            // length after each acknowledged op.
            let mut ops: Vec<Op> = Vec::new();
            let mut told: std::collections::HashMap<u64, f64> = std::collections::HashMap::new();
            {
                let engine = Engine::open(
                    d.path(),
                    EngineConfig { n_shards: writer_shards, ..Default::default() },
                )
                .unwrap();
                let mut running: Vec<u64> = Vec::new();
                for i in 0..n_ops {
                    let len_of = || std::fs::metadata(&wal).map(|m| m.len()).unwrap_or(0);
                    if running.is_empty() || g.bool() {
                        let study = g.usize(0, n_studies - 1);
                        let r = engine.ask(&ask_body(study)).unwrap();
                        if g.bool() {
                            // Intermediate report rides along; it only
                            // mutates the same trial, so the op-level
                            // prefix argument is unchanged.
                            let _ = engine.should_prune(r.trial_id, 1, 0.5).unwrap();
                        }
                        running.push(r.trial_id);
                        ops.push(Op::Ask(r.trial_id, len_of()));
                    } else {
                        let idx = g.usize(0, running.len() - 1);
                        let id = running.swap_remove(idx);
                        let v = i as f64;
                        if engine.tell(id, v).is_ok() {
                            told.insert(id, v);
                            ops.push(Op::Tell(id, v, len_of()));
                        }
                    }
                }
            }

            // Phase 2: random byte-level damage.
            let bytes = std::fs::read(&wal).unwrap_or_default();
            let damage_at = if bytes.is_empty() {
                0
            } else if g.bool() {
                // Truncation (torn tail).
                let cut = g.usize(0, bytes.len());
                std::fs::write(&wal, &bytes[..cut]).unwrap();
                cut as u64
            } else {
                // Bit flip (media corruption) — replay stops at the
                // frame containing it.
                let pos = g.usize(0, bytes.len() - 1);
                let mut b = bytes.clone();
                b[pos] ^= 0x40;
                std::fs::write(&wal, &b).unwrap();
                pos as u64
            };

            // Phase 3: recover on the reader layout and check the three
            // invariants.
            let engine = Engine::open(
                d.path(),
                EngineConfig { n_shards: reader_shards, ..Default::default() },
            )
            .unwrap();
            let mut trials: std::collections::HashMap<u64, Option<f64>> =
                std::collections::HashMap::new();
            for s in engine.studies_json().as_arr().unwrap() {
                let sid = s.get("id").as_u64().unwrap();
                for t in engine.trials_json(sid).unwrap().as_arr().unwrap() {
                    trials.insert(t.get("id").as_u64().unwrap(), t.get("value").as_f64());
                }
            }

            // No phantoms.
            for (&id, &value) in &trials {
                if !ops.iter().any(|op| matches!(op, Op::Ask(a, _) if *a == id)) {
                    return Err(format!("phantom trial {id} recovered"));
                }
                if let Some(v) = value {
                    if told.get(&id) != Some(&v) {
                        return Err(format!("phantom value {v} on trial {id}"));
                    }
                }
            }

            // Completeness + monotone prefix.
            let mut gap = false;
            for (i, op) in ops.iter().enumerate() {
                let (present, end) = match op {
                    Op::Ask(id, end) => (trials.contains_key(id), *end),
                    Op::Tell(id, v, end) => {
                        (trials.get(id).copied().flatten() == Some(*v), *end)
                    }
                };
                if end <= damage_at && !present {
                    return Err(format!(
                        "op {i} ({op:?}) fully before damage at {damage_at} was lost \
                         ({writer_shards}→{reader_shards} shards)"
                    ));
                }
                if gap && present {
                    return Err(format!(
                        "op {i} ({op:?}) survived after an earlier op was lost \
                         ({writer_shards}→{reader_shards} shards)"
                    ));
                }
                if !present {
                    gap = true;
                }
            }
            Ok(())
        });
    }

    /// Fuzz recovery across *parallel* compactions: a random
    /// ask/tell interleaving with `compact()` calls sprinkled in, the
    /// segment cuts running on a multi-thread pool
    /// (`compact_threads > 1`), then an optional torn tail on the
    /// active (highest-epoch) log, then recovery on a possibly
    /// different shard count. Invariants:
    ///
    /// * prefix — op survival is monotone in commit order (no
    ///   resurrection past a gap);
    /// * compaction durability — every op acknowledged before the last
    ///   successful `compact()` is covered by segments and must
    ///   survive any damage to the active log;
    /// * no phantoms — every recovered trial/value was acknowledged.
    #[test]
    fn prop_recovery_with_parallel_compaction_is_prefix_consistent() {
        use crate::coordinator::engine::{Engine, EngineConfig};
        use crate::json::{parse, Value};
        use crate::testutil::TempDir;

        #[derive(Debug)]
        enum Op {
            /// (trial_id, acked before the last compaction?)
            Ask(u64, bool),
            /// (trial_id, value, acked before the last compaction?)
            Tell(u64, f64, bool),
        }

        fn ask_body(study: usize) -> Value {
            parse(&format!(
                r#"{{
                "study_name": "pcfuzz-{study}",
                "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
                "direction": "minimize",
                "sampler": {{"name": "random"}}
            }}"#
            ))
            .unwrap()
        }

        /// The active (highest-epoch) log in `dir`.
        fn active_log(dir: &std::path::Path) -> Option<std::path::PathBuf> {
            let mut best: Option<(u64, std::path::PathBuf)> = None;
            for entry in std::fs::read_dir(dir).ok()? {
                let entry = entry.ok()?;
                let name = entry.file_name();
                let name = name.to_str()?;
                let epoch = if name == "wal.log" {
                    Some(0)
                } else {
                    name.strip_prefix("wal.")
                        .and_then(|r| r.strip_suffix(".log"))
                        .and_then(|e| e.parse::<u64>().ok())
                };
                if let Some(e) = epoch {
                    if best.as_ref().map(|(b, _)| e > *b).unwrap_or(true) {
                        best = Some((e, entry.path()));
                    }
                }
            }
            best.map(|(_, p)| p)
        }

        check(16, |g| {
            let shard_counts = [1usize, 4, 8];
            let writer_shards = *g.choose(&shard_counts);
            let reader_shards = *g.choose(&shard_counts);
            let compact_threads = g.usize(2, 4);
            let d = TempDir::new("prop-pc-recovery");
            let n_studies = g.usize(1, 3);
            let n_ops = g.usize(4, 28);

            let mut ops: Vec<Op> = Vec::new();
            let mut told: std::collections::HashMap<u64, f64> =
                std::collections::HashMap::new();
            let mut compactions = 0usize;
            {
                let engine = Engine::open(
                    d.path(),
                    EngineConfig {
                        n_shards: writer_shards,
                        compact_threads,
                        ..Default::default()
                    },
                )
                .unwrap();
                let mut running: Vec<u64> = Vec::new();
                for i in 0..n_ops {
                    if g.rng().chance(0.2) {
                        engine.compact().unwrap();
                        compactions += 1;
                        // Everything acked so far is now segment-covered.
                        for op in ops.iter_mut() {
                            match op {
                                Op::Ask(_, covered) | Op::Tell(_, _, covered) => *covered = true,
                            }
                        }
                    }
                    if running.is_empty() || g.bool() {
                        let study = g.usize(0, n_studies - 1);
                        let r = engine.ask(&ask_body(study)).unwrap();
                        running.push(r.trial_id);
                        ops.push(Op::Ask(r.trial_id, false));
                    } else {
                        let idx = g.usize(0, running.len() - 1);
                        let id = running.swap_remove(idx);
                        let v = i as f64;
                        if engine.tell(id, v).is_ok() {
                            told.insert(id, v);
                            ops.push(Op::Tell(id, v, false));
                        }
                    }
                }
            }

            // Torn tail on the active log only — segments and sealed
            // history must carry everything compaction covered.
            if g.bool() {
                if let Some(log) = active_log(d.path()) {
                    let bytes = std::fs::read(&log).unwrap_or_default();
                    if !bytes.is_empty() {
                        let cut = g.usize(0, bytes.len());
                        std::fs::write(&log, &bytes[..cut]).unwrap();
                    }
                }
            }

            let engine = Engine::open(
                d.path(),
                EngineConfig { n_shards: reader_shards, ..Default::default() },
            )
            .unwrap();
            let mut trials: std::collections::HashMap<u64, Option<f64>> =
                std::collections::HashMap::new();
            for s in engine.studies_json().as_arr().unwrap() {
                let sid = s.get("id").as_u64().unwrap();
                for t in engine.trials_json(sid).unwrap().as_arr().unwrap() {
                    trials.insert(t.get("id").as_u64().unwrap(), t.get("value").as_f64());
                }
            }

            // No phantoms.
            for (&id, &value) in &trials {
                if !ops.iter().any(|op| matches!(op, Op::Ask(a, _) if *a == id)) {
                    return Err(format!("phantom trial {id} recovered"));
                }
                if let Some(v) = value {
                    if told.get(&id) != Some(&v) {
                        return Err(format!("phantom value {v} on trial {id}"));
                    }
                }
            }

            // Compaction durability + monotone prefix.
            let mut gap = false;
            for (i, op) in ops.iter().enumerate() {
                let (present, covered) = match op {
                    Op::Ask(id, covered) => (trials.contains_key(id), *covered),
                    Op::Tell(id, v, covered) => {
                        (trials.get(id).copied().flatten() == Some(*v), *covered)
                    }
                };
                if covered && !present {
                    return Err(format!(
                        "op {i} ({op:?}) was covered by a compaction ({compactions} total) \
                         but lost ({writer_shards}→{reader_shards} shards, \
                         {compact_threads} cut threads)"
                    ));
                }
                if gap && present {
                    return Err(format!(
                        "op {i} ({op:?}) survived after an earlier op was lost \
                         ({writer_shards}→{reader_shards} shards, \
                         {compact_threads} cut threads)"
                    ));
                }
                if !present {
                    gap = true;
                }
            }
            Ok(())
        });
    }

    /// Fuzz the materialized-view read path under concurrent writers:
    /// several threads ask (in batches), should_prune, tell and fail
    /// against one study on a durable engine at 1/4/8 shards while the
    /// main thread collects view snapshots and pages them through
    /// random cursors, with a compaction cut mid-pagination. Invariants:
    ///
    /// * snapshot ordering — across the collected sequence, epoch and
    ///   trial count never decrease, slot identity is stable, terminal
    ///   states are sticky and values immutable once set (every view is
    ///   *some* acknowledged prefix, never a rollback);
    /// * no torn batches — every acknowledged ask batch is all-present
    ///   or all-absent in every snapshot (batch-atomic publication);
    /// * no phantoms — every completed trial in the final view carries
    ///   exactly the value a writer's acknowledged tell recorded;
    /// * page integrity — for any limit, walking a snapshot's cursor
    ///   chain through JSON serialization reproduces exactly the
    ///   snapshot's trial ids in slot order, no gaps, no duplicates;
    /// * recovery — after restart (possibly at a different shard count)
    ///   the rebuilt view matches the recovered engine state, the event
    ///   log is dense with watermark == terminal-trial count, and a
    ///   second replay rebuilds the identical event sequence.
    #[test]
    fn prop_view_pages_are_prefix_consistent_under_concurrent_writes() {
        use crate::coordinator::engine::{Engine, EngineConfig};
        use crate::coordinator::trial::TrialState;
        use crate::coordinator::views::{render_trials_page, Cursor};
        use crate::json::parse;
        use crate::rng::{mix, Rng};
        use crate::testutil::TempDir;
        use std::collections::{HashMap, HashSet};
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::{Arc, Mutex};

        fn body() -> crate::json::Value {
            parse(
                r#"{
                "study_name": "rp-fuzz",
                "properties": {"x": {"low": 0.0, "high": 1.0}},
                "direction": "minimize",
                "sampler": {"name": "random"}
            }"#,
            )
            .unwrap()
        }

        check(8, |g| {
            let shard_counts = [1usize, 4, 8];
            let writer_shards = *g.choose(&shard_counts);
            let reader_shards = *g.choose(&shard_counts);
            let d = TempDir::new("prop-read-path");
            let engine = Arc::new(
                Engine::open(
                    d.path(),
                    EngineConfig { n_shards: writer_shards, ..Default::default() },
                )
                .unwrap(),
            );
            // Seed the study so readers have a stable id from the start.
            let first = engine.ask(&body()).unwrap();
            let sid = first.study_id;
            engine.tell(first.trial_id, 0.5).unwrap();

            let batches: Arc<Mutex<Vec<Vec<u64>>>> = Arc::new(Mutex::new(Vec::new()));
            let told: Arc<Mutex<HashMap<u64, f64>>> = Arc::new(Mutex::new(HashMap::new()));
            told.lock().unwrap().insert(first.trial_id, 0.5);
            let n_writers = g.usize(2, 3);
            let ops_per_writer = g.usize(4, 10);
            let case_seed = g.rng().below(1 << 62);
            let writers_done = Arc::new(AtomicU64::new(0));

            let mut handles = Vec::new();
            for w in 0..n_writers {
                let engine = engine.clone();
                let batches = batches.clone();
                let told = told.clone();
                let writers_done = writers_done.clone();
                let seed = mix(case_seed, w as u64);
                handles.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(seed);
                    let mut seqno = 0u64;
                    for _ in 0..ops_per_writer {
                        let k = 1 + rng.below(3) as usize;
                        let replies = engine.ask_n_as(&body(), k, None).unwrap();
                        batches
                            .lock()
                            .unwrap()
                            .push(replies.iter().map(|r| r.trial_id).collect());
                        for r in &replies {
                            if rng.chance(0.3) {
                                let _ = engine.should_prune(r.trial_id, 1, 0.5);
                            }
                            if rng.chance(0.15) {
                                let _ = engine.fail(r.trial_id);
                            } else if rng.chance(0.8) {
                                // Integer-valued so the WAL roundtrip is
                                // bit-exact (matches the recovery props).
                                let v = (w as u64 * 1_000_000 + seqno) as f64;
                                seqno += 1;
                                if engine.tell(r.trial_id, v).is_ok() {
                                    told.lock().unwrap().insert(r.trial_id, v);
                                }
                            }
                            // else: left running (reaped-in-production case).
                        }
                    }
                    writers_done.fetch_add(1, Ordering::Release);
                }));
            }

            // Reader: sample the published snapshot while writers run.
            let mut snapshots = Vec::new();
            while writers_done.load(Ordering::Acquire) < n_writers as u64 {
                if let Some(v) = engine.views().study_view(sid) {
                    snapshots.push(v);
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            for h in handles {
                h.join().expect("writer thread");
            }
            snapshots.push(engine.views().study_view(sid).expect("final view"));

            // Snapshot ordering: monotone epoch/count, sticky terminals.
            for pair in snapshots.windows(2) {
                let (a, b) = (&pair[0], &pair[1]);
                assert_holds(
                    b.epoch >= a.epoch,
                    format!("epoch rollback {} -> {}", a.epoch, b.epoch),
                )?;
                assert_holds(
                    b.trials.len() >= a.trials.len(),
                    format!("trial count shrank {} -> {}", a.trials.len(), b.trials.len()),
                )?;
                for (i, ta) in a.trials.iter().enumerate() {
                    let tb = &b.trials[i];
                    assert_holds(ta.id == tb.id, format!("slot {i} changed identity"))?;
                    if ta.state != TrialState::Running {
                        assert_holds(
                            ta.state == tb.state,
                            format!("terminal state reverted on trial {}", ta.id),
                        )?;
                        if let Some(v) = ta.value {
                            assert_holds(
                                tb.value == Some(v),
                                format!("value changed on trial {}", ta.id),
                            )?;
                        }
                    }
                }
            }

            // Batch atomicity: no snapshot shows part of an ask batch.
            {
                let batches = batches.lock().unwrap();
                for snap in &snapshots {
                    let ids: HashSet<u64> = snap.trials.iter().map(|t| t.id).collect();
                    for batch in batches.iter() {
                        let present = batch.iter().filter(|id| ids.contains(id)).count();
                        assert_holds(
                            present == 0 || present == batch.len(),
                            format!("torn batch {batch:?}: {present}/{}", batch.len()),
                        )?;
                    }
                }
            }

            // No phantoms: completed values are exactly acknowledged tells.
            {
                let told = told.lock().unwrap();
                let last = snapshots.last().unwrap();
                for t in last.trials.iter() {
                    if t.state == TrialState::Completed {
                        assert_holds(
                            told.get(&t.id) == t.value.as_ref(),
                            format!("phantom value {:?} on trial {}", t.value, t.id),
                        )?;
                    }
                }
            }

            // Page integrity over random limits, compacting mid-walk.
            let n_snaps = snapshots.len();
            let picks =
                [0, n_snaps / 2, n_snaps - 1, g.usize(0, n_snaps - 1)];
            let mut compacted = false;
            for &p in &picks {
                let snap = &snapshots[p];
                let limit = g.usize(1, snap.trials.len().max(1));
                let mut ids = Vec::new();
                let mut cursor = Cursor { epoch: snap.epoch, index: 0 };
                loop {
                    let page = parse(&render_trials_page(snap, cursor, limit, None))
                        .map_err(|e| format!("invalid page json: {e}"))?;
                    for t in page.get("trials").as_arr().ok_or("page missing trials")? {
                        ids.push(t.get("id").as_u64().ok_or("trial missing id")?);
                    }
                    match page.get("next_cursor").as_str() {
                        Some(c) => {
                            cursor = Cursor::decode(c)
                                .map_err(|e| format!("bad next_cursor: {e}"))?;
                        }
                        None => break,
                    }
                    if !compacted {
                        // A segment cut mid-pagination must not disturb
                        // the walk (views never touch storage).
                        engine.compact().unwrap();
                        compacted = true;
                    }
                }
                let want: Vec<u64> = snap.trials.iter().map(|t| t.id).collect();
                assert_holds(
                    ids == want,
                    format!("page walk mismatch: {} ids paged, {} in view", ids.len(), want.len()),
                )?;
            }

            // Recovery: restart (new shard layout), views rebuilt to
            // match engine state; event log dense and deterministic.
            drop(engine);
            let reopened = Engine::open(
                d.path(),
                EngineConfig { n_shards: reader_shards, ..Default::default() },
            )
            .unwrap();
            let view = reopened
                .views()
                .study_view(sid)
                .ok_or("study view missing after recovery")?;
            let trials = reopened.trials_json(sid).ok_or("study missing after recovery")?;
            let arr = trials.as_arr().ok_or("trials_json not an array")?;
            assert_holds(
                arr.len() == view.trials.len(),
                format!("recovered view has {} trials, engine {}", view.trials.len(), arr.len()),
            )?;
            for (t, lite) in arr.iter().zip(view.trials.iter()) {
                assert_holds(t.get("id").as_u64() == Some(lite.id), "rebuilt id mismatch")?;
                assert_holds(
                    t.get("state").as_str() == Some(lite.state.as_str()),
                    format!("rebuilt state mismatch on trial {}", lite.id),
                )?;
                assert_holds(
                    t.get("value").as_f64() == lite.value,
                    format!("rebuilt value mismatch on trial {}", lite.id),
                )?;
            }
            let ev1 = reopened
                .views()
                .events_after(sid, 0, usize::MAX)
                .ok_or("event log missing after recovery")?;
            for (i, e) in ev1.events.iter().enumerate() {
                assert_holds(e.seq == i as u64 + 1, "rebuilt event seq not dense")?;
            }
            let n_terminal =
                view.trials.iter().filter(|t| t.state != TrialState::Running).count() as u64;
            assert_holds(
                ev1.watermark == n_terminal,
                format!("watermark {} != {} terminal trials", ev1.watermark, n_terminal),
            )?;
            drop(reopened);
            let again = Engine::open(
                d.path(),
                EngineConfig { n_shards: *g.choose(&shard_counts), ..Default::default() },
            )
            .unwrap();
            let ev2 = again
                .views()
                .events_after(sid, 0, usize::MAX)
                .ok_or("event log missing on second replay")?;
            let k1: Vec<(u64, &str)> =
                ev1.events.iter().map(|e| (e.trial_id, e.kind.as_str())).collect();
            let k2: Vec<(u64, &str)> =
                ev2.events.iter().map(|e| (e.trial_id, e.kind.as_str())).collect();
            assert_holds(
                k1 == k2,
                format!("event replay not deterministic: {} vs {} events", k1.len(), k2.len()),
            )
        });
    }

    /// Fuzz the fleet's slot accounting: a random schedule of
    /// admit+bind / finish / requeue / re-handout operations over
    /// random sites, studies, tenants and quotas must keep the
    /// scheduler's three counter ledgers (per-site, per-study,
    /// per-tenant) exactly equal to the live lease table — the
    /// "sum of per-site counts == live lease count" invariant that a
    /// masked double-release (the old `saturating_sub`) would silently
    /// violate.
    #[test]
    fn prop_fleet_slot_accounting_matches_live_leases() {
        use crate::fleet::{Fleet, FleetConfig, FleetState, QuotaPolicy};

        fn check_invariant(st: &FleetState) -> PropResult {
            let live = st.leases.len() as u64;
            let with_tenant =
                st.leases.iter().filter(|(_, info)| info.tenant.is_some()).count() as u64;
            assert_holds(
                st.sched.total_active() == live,
                format!("site slots {} != live leases {live}", st.sched.total_active()),
            )?;
            assert_holds(
                st.sched.study_active_total() == live,
                format!("study slots {} != live leases {live}", st.sched.study_active_total()),
            )?;
            assert_holds(
                st.sched.tenant_active_total() == with_tenant,
                format!(
                    "tenant slots {} != tenant leases {with_tenant}",
                    st.sched.tenant_active_total()
                ),
            )
        }

        check(48, |g| {
            let sites = ["cloud", "spot", "hpc"];
            let tenants: [Option<&str>; 3] = [None, Some("alice"), Some("bob")];
            let config = FleetConfig {
                lease_timeout: Some(1e9),
                policy: QuotaPolicy {
                    site_quota: g.usize(0, 3) as u32,
                    study_quota: g.usize(0, 3) as u32,
                    tenant_quota: g.usize(0, 2) as u32,
                    ..Default::default()
                },
                ..Default::default()
            };
            let fleet = Fleet::new(config);
            let mut st = fleet.lock();
            let mut workers = Vec::new();
            for i in 0..g.usize(1, 4) {
                let id = st.registry.next_id();
                let site = *g.choose(&sites);
                st.registry
                    .apply_register(id, &format!("w{i}"), site, "gpu", 0.0, 1e9);
                workers.push(id);
            }
            let mut next_tid = 1u64;
            // (trial, study) of live leases / queued requeues we drive.
            let mut live: Vec<(u64, String)> = Vec::new();
            let mut queued: Vec<String> = Vec::new();
            for _ in 0..g.usize(1, 48) {
                match g.usize(0, 3) {
                    // Fresh admission: admit + bind (or nothing on 429).
                    0 => {
                        let w = *g.choose(&workers);
                        let study = format!("s{}", g.usize(0, 2));
                        let tenant = *g.choose(&tenants);
                        if let Ok(site) = st.admit(w, &study, tenant, 0.0, &fleet.config) {
                            st.bind(next_tid, w, &study, &site, tenant, 0.0);
                            live.push((next_tid, study));
                            next_tid += 1;
                        }
                    }
                    // Terminal transition: the lease-gated single release.
                    1 => {
                        if !live.is_empty() {
                            let (tid, study) = live.swap_remove(g.usize(0, live.len() - 1));
                            st.finish_trial(tid, &study);
                            // A second finish must be a no-op, not an
                            // underflow (lease already gone).
                            st.finish_trial(tid, &study);
                        }
                    }
                    // Worker loss: requeue exactly once.
                    2 => {
                        if !live.is_empty() {
                            let (tid, study) = live.swap_remove(g.usize(0, live.len() - 1));
                            let w = st.leases.get(tid).expect("live lease").worker;
                            assert_holds(st.requeue(tid, w, 0.0), "requeue of live lease")?;
                            assert_holds(!st.requeue(tid, w, 0.0), "second requeue is a no-op")?;
                            queued.push(study);
                        }
                    }
                    // Re-handout of a queued trial (the engine's
                    // pop → admit → bind-or-push-front path).
                    _ => {
                        if !queued.is_empty() {
                            let study = queued.swap_remove(g.usize(0, queued.len() - 1));
                            let Some(tid) = st.leases.pop_front(&study) else {
                                return Err(format!("queue for {study} unexpectedly empty"));
                            };
                            let w = *g.choose(&workers);
                            let tenant = *g.choose(&tenants);
                            match st.admit(w, &study, tenant, 0.0, &fleet.config) {
                                Ok(site) => {
                                    st.bind(tid, w, &study, &site, tenant, 0.0);
                                    live.push((tid, study));
                                }
                                Err(_) => {
                                    st.leases.push_front(&study, tid, 0.0);
                                    queued.push(study);
                                }
                            }
                        }
                    }
                }
                check_invariant(&st)?;
            }
            // Drain: finish every live lease and drop every queued
            // trial; all three ledgers must return to exactly zero.
            for (tid, study) in live.drain(..) {
                st.finish_trial(tid, &study);
            }
            for study in queued.drain(..) {
                if let Some(tid) = st.leases.pop_front(&study) {
                    st.finish_trial(tid, &study);
                }
            }
            check_invariant(&st)?;
            assert_holds(st.sched.total_active() == 0, "site ledger drained")?;
            assert_holds(st.sched.tenant_active_total() == 0, "tenant ledger drained")?;
            assert_holds(st.leases.queue_depth() == 0, "queue drained")
        });
    }

    /// Fuzz the replication stream: a random ask/tell interleaving on a
    /// durable primary at 1/4/8 shards, shipped to a durable follower
    /// through fetches with random page sizes, random stream cuts
    /// (stop fetching mid-stream, later resume from the follower's
    /// cursor) and random *overlapped* reconnects (resume from an older
    /// seq, so the same records are delivered twice). Invariants:
    ///
    /// * prefix — at every stream position the follower's tells are a
    ///   subset of the primary's with identical values (the follower
    ///   never invents or reorders state);
    /// * no phantoms — every follower value was an acknowledged tell;
    /// * duplicate delivery is idempotent — overlapped fetches change
    ///   nothing;
    /// * convergence — after a full drain the follower's tells equal
    ///   the primary's exactly, and promotion accepts new writes.
    #[test]
    fn prop_follower_stream_is_prefix_consistent() {
        use crate::coordinator::engine::{Engine, EngineConfig};
        use crate::json::{parse, Value};
        use crate::store::ReplFetch;
        use crate::testutil::TempDir;
        use std::collections::HashMap;

        fn ask_body(study: usize) -> Value {
            parse(&format!(
                r#"{{
                "study_name": "repl-fuzz-{study}",
                "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
                "direction": "minimize",
                "sampler": {{"name": "random"}}
            }}"#
            ))
            .unwrap()
        }

        fn tells(engine: &Engine) -> HashMap<u64, f64> {
            let mut out = HashMap::new();
            for s in engine.studies_json().as_arr().unwrap() {
                let sid = s.get("id").as_u64().unwrap();
                for t in engine.trials_json(sid).unwrap().as_arr().unwrap() {
                    if let Some(v) = t.get("value").as_f64() {
                        out.insert(t.get("id").as_u64().unwrap(), v);
                    }
                }
            }
            out
        }

        fn prefix_ok(
            primary: &Engine,
            follower: &Engine,
            told: &HashMap<u64, f64>,
        ) -> PropResult {
            let p = tells(primary);
            for (id, v) in tells(follower) {
                assert_holds(
                    p.get(&id) == Some(&v),
                    format!("follower tell {id}={v} absent on primary"),
                )?;
                assert_holds(
                    told.get(&id) == Some(&v),
                    format!("phantom follower value {v} on trial {id}"),
                )?;
            }
            Ok(())
        }

        check(12, |g| {
            let shard_counts = [1usize, 4, 8];
            let shards = *g.choose(&shard_counts);
            let dp = TempDir::new("prop-repl-p");
            let df = TempDir::new("prop-repl-f");
            let primary = Engine::open(
                dp.path(),
                EngineConfig { n_shards: shards, ..Default::default() },
            )
            .unwrap();
            let follower = Engine::open(
                df.path(),
                EngineConfig { follower: true, n_shards: shards, ..Default::default() },
            )
            .unwrap();
            let source = primary.repl_source().expect("primary replication log");

            let n_studies = g.usize(1, 3);
            let n_ops = g.usize(4, 32);
            let mut told: HashMap<u64, f64> = HashMap::new();
            let mut running: Vec<u64> = Vec::new();
            for i in 0..n_ops {
                if running.is_empty() || g.bool() {
                    let r = primary.ask(&ask_body(g.usize(0, n_studies - 1))).unwrap();
                    running.push(r.trial_id);
                } else {
                    let id = running.swap_remove(g.usize(0, running.len() - 1));
                    let v = i as f64;
                    if primary.tell(id, v).is_ok() {
                        told.insert(id, v);
                    }
                }
                // Ship a random slice of the stream. Stopping after a
                // bounded number of fetches *is* the stream cut: the
                // next round reconnects and resumes from the cursor —
                // sometimes from an older seq (overlapped redelivery).
                if g.bool() {
                    let mut budget = g.usize(1, 6);
                    loop {
                        let overlap = if g.bool() { g.usize(0, 3) as u64 } else { 0 };
                        let from = follower.repl_next().saturating_sub(overlap);
                        match source.fetch(from, g.usize(1, 5)) {
                            ReplFetch::Batches { records, next: _, primary_next } => {
                                follower
                                    .apply_repl_batch(&records, primary_next)
                                    .map_err(|e| format!("apply: {e}"))?;
                            }
                            ReplFetch::UpToDate { next } => {
                                follower
                                    .apply_repl_batch(&[], next)
                                    .map_err(|e| format!("apply(empty): {e}"))?;
                                break;
                            }
                            ReplFetch::TooOld { oldest } => {
                                return Err(format!("window overrun (oldest {oldest})"));
                            }
                        }
                        budget -= 1;
                        if budget == 0 {
                            break;
                        }
                    }
                    prefix_ok(&primary, &follower, &told)?;
                }
            }

            // Full drain: the follower must converge to the primary.
            loop {
                match source.fetch(follower.repl_next(), 4096) {
                    ReplFetch::Batches { records, next: _, primary_next } => {
                        follower
                            .apply_repl_batch(&records, primary_next)
                            .map_err(|e| format!("drain apply: {e}"))?;
                    }
                    ReplFetch::UpToDate { next } => {
                        follower
                            .apply_repl_batch(&[], next)
                            .map_err(|e| format!("drain apply(empty): {e}"))?;
                        break;
                    }
                    ReplFetch::TooOld { oldest } => {
                        return Err(format!("drain window overrun (oldest {oldest})"));
                    }
                }
            }
            let p = tells(&primary);
            let f = tells(&follower);
            assert_holds(
                p == f,
                format!(
                    "drained follower diverged: {} tells vs {} on primary ({shards} shards)",
                    f.len(),
                    p.len()
                ),
            )?;
            assert_holds(
                f.len() == told.len(),
                format!("{} tells on follower, {} acknowledged", f.len(), told.len()),
            )?;

            // Promotion: the follower flips writable and takes writes.
            follower.promote().map_err(|e| format!("promote: {e}"))?;
            let r = follower
                .ask(&ask_body(0))
                .map_err(|e| format!("post-promote ask: {e}"))?;
            follower
                .tell(r.trial_id, 0.25)
                .map_err(|e| format!("post-promote tell: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn passing_property_passes() {
        check(64, |g| {
            let v = g.vec(0..=16, |g| g.i64(-5, 5));
            assert_holds(v.len() <= 16, "len bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(64, |g| {
            let x = g.i64(0, 100);
            assert_holds(x < 90, format!("x={x}"))
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check(128, |g| {
            let x = g.i64(-3, 9);
            assert_holds((-3..=9).contains(&x), format!("x={x}"))
        });
    }
}

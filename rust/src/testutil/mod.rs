//! Test utilities, including a miniature property-testing harness and a
//! deterministic crash-injection harness.
//!
//! `proptest` is not available in this offline build, so `prop` provides
//! the same methodological role: seeded random generators, a configurable
//! number of cases, and greedy shrinking on failure. Coordinator
//! invariants (routing, batching, state machines) are exercised through
//! it — see the `proptest` substitution note in DESIGN.md §3. `crash`
//! arms named kill-points inside the store so recovery can be driven
//! through every step of the compaction protocol. `sched` is a
//! deterministic interleaving checker (a shuttle-style controlled
//! scheduler) and `models` the concurrency protocol miniatures it
//! exercises — the dynamic half of the PR-10 concurrency tooling,
//! alongside the `hopaas-lint` static analysis in `crate::analysis`.

pub mod crash;
pub mod models;
pub mod prop;
pub mod sched;

use std::net::TcpListener;

/// Find a free localhost port by binding port 0 and dropping the listener.
/// Subject to a benign race, acceptable in tests.
pub fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .expect("bind 127.0.0.1:0")
        .local_addr()
        .expect("local_addr")
        .port()
}

/// A scratch directory deleted on drop.
pub struct TempDir {
    path: std::path::PathBuf,
}

impl TempDir {
    /// Create a fresh temp dir under the system temp root.
    pub fn new(tag: &str) -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static N: AtomicU64 = AtomicU64::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "hopaas-test-{tag}-{}-{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    /// Path of the directory.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_created_and_removed() {
        let p;
        {
            let d = TempDir::new("t");
            p = d.path().to_path_buf();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn free_port_nonzero() {
        assert_ne!(free_port(), 0);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component of the service (samplers, the node
//! simulator, noise generation for GAN training) draws from [`Rng`], a
//! xoshiro256++ generator seeded through splitmix64. Determinism matters
//! here: benchmark runs and property tests must be reproducible from a
//! single `u64` seed, and trials executed on different nodes must be able
//! to share a seed derived from the trial id.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes
/// BigCrush; more than adequate for sampling hyperparameters and
/// simulation noise.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64 step — used to expand a 64-bit seed into the xoshiro state
/// and as a cheap stateless mixer for deriving sub-seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Mix two 64-bit values into one (for deriving per-entity seeds such as
/// `seed ⊕ trial_id`).
#[inline]
pub fn mix(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut s)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (splits the stream).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), rejection-free Lemire method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller (one value per call; the twin is
    /// discarded for simplicity — callers are not throughput-bound here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index according to non-negative weights (linear scan —
    /// weight vectors here are tiny).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a slice with standard normals (used for GAN latent noise).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        // Box-Muller pairs: generate two at a time.
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = self.f64().max(1e-300);
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let t = 2.0 * std::f64::consts::PI * u2;
            out[i] = (r * t.cos()) as f32;
            out[i + 1] = (r * t.sin()) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.normal() as f32;
        }
    }

    /// Fill a slice with uniforms in `[lo, hi)`.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.f64() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn int_range_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 6];
        for _ in 0..1000 {
            let v = r.int_range(-2, 3);
            assert!((-2..=3).contains(&v));
            seen[(v + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range hit");
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn weighted_proportions() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            counts[r.weighted(&[1.0, 2.0, 3.0])] += 1;
        }
        let p1 = counts[1] as f64 / n as f64;
        assert!((p1 - 2.0 / 6.0).abs() < 0.02, "p1={p1}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fill_normal_f32_odd_len() {
        let mut r = Rng::new(19);
        let mut buf = vec![0f32; 7];
        r.fill_normal_f32(&mut buf);
        assert!(buf.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(23);
        let mut a = r.fork();
        let mut b = r.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn mix_differs_by_argument() {
        assert_ne!(mix(1, 2), mix(2, 1));
        assert_ne!(mix(0, 0), mix(0, 1));
    }
}

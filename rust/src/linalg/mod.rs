//! Small dense linear-algebra substrate for the GP sampler.
//!
//! The Gaussian-process sampler needs: symmetric positive-definite
//! factorization (Cholesky), triangular solves, and log-determinants, for
//! matrices up to a few hundred rows (the trial history of one study).
//! A tight, allocation-conscious column-major implementation is plenty.

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            y[r] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }
}

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Chol {
    /// Lower factor, row-major n×n (upper part zero).
    pub l: Mat,
}

/// Error for non-SPD input.
#[derive(Debug, thiserror::Error)]
#[error("matrix not positive definite at pivot {pivot} (value {value})")]
pub struct NotSpd {
    pub pivot: usize,
    pub value: f64,
}

/// Cholesky factorization `A = L Lᵀ`. `A` must be symmetric; only the
/// lower triangle is read.
pub fn cholesky(a: &Mat) -> Result<Chol, NotSpd> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return Err(NotSpd { pivot: i, value: sum });
                }
                *l.at_mut(i, j) = sum.sqrt();
            } else {
                *l.at_mut(i, j) = sum / l.at(j, j);
            }
        }
    }
    Ok(Chol { l })
}

impl Chol {
    /// Solve `A x = b` via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.at(i, k) * y[k];
            }
            y[i] = s / self.l.at(i, i);
        }
        // Back: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l.at(k, i) * x[k];
            }
            x[i] = s / self.l.at(i, i);
        }
        x
    }

    /// Solve `L v = b` only (forward substitution) — used for the GP
    /// predictive variance.
    pub fn forward(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.at(i, k) * y[k];
            }
            y[i] = s / self.l.at(i, i);
        }
        y
    }

    /// `log det A = 2 Σ log L_ii`.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Standard-normal PDF.
#[inline]
pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard-normal CDF via erf (Abramowitz-Stegun 7.1.26, |err| < 1.5e-7
/// — far below the noise floor of any acquisition decision).
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Error function approximation.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn cholesky_known() {
        // A = [[4,2],[2,3]] -> L = [[2,0],[1,sqrt(2)]]
        let a = Mat::from_rows(vec![vec![4.0, 2.0], vec![2.0, 3.0]]);
        let c = cholesky(&a).unwrap();
        assert!((c.l.at(0, 0) - 2.0).abs() < 1e-12);
        assert!((c.l.at(1, 0) - 1.0).abs() < 1e-12);
        assert!((c.l.at(1, 1) - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(c.l.at(0, 1), 0.0);
    }

    #[test]
    fn solve_recovers_x() {
        let a = Mat::from_rows(vec![
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let c = cholesky(&a).unwrap();
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.matvec(&x_true);
        let x = c.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10, "{x:?}");
        }
    }

    #[test]
    fn non_spd_rejected() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn log_det_matches() {
        let a = Mat::from_rows(vec![vec![4.0, 0.0], vec![0.0, 9.0]]);
        let c = cholesky(&a).unwrap();
        assert!((c.log_det() - (36f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn prop_solve_random_spd() {
        prop::check(60, |g| {
            let n = g.usize(1, 8);
            // Build SPD as B Bᵀ + n·I.
            let mut b = Mat::zeros(n, n);
            for v in b.data.iter_mut() {
                *v = g.f64(-1.0, 1.0);
            }
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += b.at(i, k) * b.at(j, k);
                    }
                    *a.at_mut(i, j) = s + if i == j { n as f64 } else { 0.0 };
                }
            }
            let x_true: Vec<f64> = (0..n).map(|_| g.f64(-3.0, 3.0)).collect();
            let rhs = a.matvec(&x_true);
            let c = cholesky(&a).map_err(|e| e.to_string())?;
            let x = c.solve(&rhs);
            let err: f64 = x
                .iter()
                .zip(&x_true)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            prop::assert_holds(err < 1e-8, format!("max err {err}"))
        });
    }

    #[test]
    fn norm_cdf_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((norm_cdf(-1.96) - 0.025).abs() < 1e-3);
        assert!(norm_cdf(8.0) > 0.999999);
    }

    #[test]
    fn erf_symmetry() {
        prop::check(100, |g| {
            let x = g.f64(-4.0, 4.0);
            prop::assert_holds((erf(x) + erf(-x)).abs() < 1e-12, format!("x={x}"))
        });
    }
}

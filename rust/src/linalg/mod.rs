//! Small dense linear-algebra substrate for the GP sampler.
//!
//! The Gaussian-process sampler needs: symmetric positive-definite
//! factorization (Cholesky), triangular solves, and log-determinants, for
//! matrices up to a few hundred rows (the trial history of one study).
//! A tight, allocation-conscious column-major implementation is plenty.

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            y[r] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }
}

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Chol {
    /// Lower factor, row-major n×n (upper part zero).
    pub l: Mat,
}

/// Error for non-SPD input.
#[derive(Debug, thiserror::Error)]
#[error("matrix not positive definite at pivot {pivot} (value {value})")]
pub struct NotSpd {
    pub pivot: usize,
    pub value: f64,
}

/// Cholesky factorization `A = L Lᵀ`. `A` must be symmetric; only the
/// lower triangle is read.
pub fn cholesky(a: &Mat) -> Result<Chol, NotSpd> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return Err(NotSpd { pivot: i, value: sum });
                }
                *l.at_mut(i, j) = sum.sqrt();
            } else {
                *l.at_mut(i, j) = sum / l.at(j, j);
            }
        }
    }
    Ok(Chol { l })
}

impl Chol {
    /// Solve `A x = b` via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.at(i, k) * y[k];
            }
            y[i] = s / self.l.at(i, i);
        }
        // Back: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l.at(k, i) * x[k];
            }
            x[i] = s / self.l.at(i, i);
        }
        x
    }

    /// Solve `L v = b` only (forward substitution) — used for the GP
    /// predictive variance.
    pub fn forward(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.at(i, k) * y[k];
            }
            y[i] = s / self.l.at(i, i);
        }
        y
    }

    /// `log det A = 2 Σ log L_ii`.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Log-density of a truncated-Gaussian Parzen mixture on `[0, 1]` with a
/// uniform prior component, evaluated at `x`.
///
/// The mixture is stored flat (`mus` / `sigmas` / `norms` as parallel
/// slices) so the inner loop streams three contiguous arrays: density =
/// `w + Σ w·N(x; μᵢ, σᵢ)/zᵢ` where `zᵢ` (`norms`) is the in-`[0,1]`
/// mass of component i and `w` the shared component weight.
pub fn trunc_mixture_log_pdf(x: f64, mus: &[f64], sigmas: &[f64], norms: &[f64], w: f64) -> f64 {
    let mut acc = w; // uniform prior on [0,1]: density w·1
    let sqrt_2pi = (2.0 * std::f64::consts::PI).sqrt();
    for ((&m, &s), &z) in mus.iter().zip(sigmas).zip(norms) {
        let t = (x - m) / s;
        let pdf = (-0.5 * t * t).exp() / (s * sqrt_2pi);
        acc += w * pdf / z;
    }
    acc.max(1e-300).ln()
}

/// Batched variant of [`trunc_mixture_log_pdf`]: evaluate the mixture
/// log-density at every point, writing into `out` (same length).
///
/// Components stream in the *outer* loop so the parameter arrays —
/// up to ~1000 entries for a TPE bad mixture — are read exactly once
/// whatever the candidate count, with the per-component constants
/// (`σ·√2π`) hoisted out of the point loop; the accumulators stay in a
/// cache-line-sized buffer. Per accumulator the additions happen in
/// component order, exactly as the scalar routine performs them, so
/// the results are bit-identical to calling [`trunc_mixture_log_pdf`]
/// once per point.
pub fn trunc_mixture_log_pdf_many(
    points: &[f64],
    mus: &[f64],
    sigmas: &[f64],
    norms: &[f64],
    w: f64,
    out: &mut [f64],
) {
    assert_eq!(points.len(), out.len());
    let sqrt_2pi = (2.0 * std::f64::consts::PI).sqrt();
    for acc in out.iter_mut() {
        *acc = w; // uniform prior on [0,1]: density w·1
    }
    for ((&m, &s), &z) in mus.iter().zip(sigmas).zip(norms) {
        let denom = s * sqrt_2pi;
        for (&x, acc) in points.iter().zip(out.iter_mut()) {
            let t = (x - m) / s;
            *acc += w * ((-0.5 * t * t).exp() / denom) / z;
        }
    }
    for acc in out.iter_mut() {
        *acc = acc.max(1e-300).ln();
    }
}

/// Precomputed log-density of a truncated-Gaussian mixture on a dense
/// uniform grid over `[0, 1]`, for O(1) interpolated lookups.
///
/// Built once per sampler fit, queried per candidate: the TPE scoring
/// loop evaluates the "bad" mixture (up to ~1000 components) at every
/// candidate, which is the dominant per-ask cost at large histories.
/// Each Gaussian is accumulated only within ±8σ of its mean using the
/// constant-ratio recurrence `g(x+Δ) = g(x)·c·qᵏ` (two `exp` calls per
/// component, two multiplies per node), so building the grid costs far
/// less than one exact dense evaluation pass.
#[derive(Clone, Debug)]
pub struct DensityGrid {
    /// Log-density at nodes `j / (len-1)`, `j = 0..len`.
    log_pdf: Vec<f64>,
}

impl DensityGrid {
    /// Number of grid cells (nodes = bins + 1). 1024 keeps interpolation
    /// error in the scored log-density well below the spacing between
    /// distinct candidates' scores.
    pub const DEFAULT_BINS: usize = 1024;

    /// Tabulate the mixture of [`trunc_mixture_log_pdf`] on `bins + 1`
    /// uniform nodes spanning `[0, 1]`.
    pub fn from_trunc_mixture(
        mus: &[f64],
        sigmas: &[f64],
        norms: &[f64],
        w: f64,
        bins: usize,
    ) -> DensityGrid {
        let bins = bins.max(2);
        let n_nodes = bins + 1;
        let dx = 1.0 / bins as f64;
        // Uniform prior contributes density w everywhere.
        let mut pdf = vec![w; n_nodes];
        let inv_sqrt_2pi = 1.0 / (2.0 * std::f64::consts::PI).sqrt();
        for ((&m, &s), &z) in mus.iter().zip(sigmas).zip(norms) {
            let amp = w * inv_sqrt_2pi / s / z;
            // Restrict to ±8σ: beyond that the density is < 1e-14·amp.
            let lo = (((m - 8.0 * s) / dx).floor().max(0.0)) as usize;
            let hi = ((((m + 8.0 * s) / dx).ceil()) as usize).min(bins);
            if lo > hi {
                continue;
            }
            // g(x_j) = exp(-(x_j-m)²/2σ²) via the recurrence
            //   g_{j+1} = g_j · step_j,  step_{j+1} = step_j · q
            // with q = exp(-Δ²/σ²) constant — exact in real arithmetic.
            let x0 = lo as f64 * dx;
            let t0 = (x0 - m) / s;
            let mut g = (-0.5 * t0 * t0).exp();
            let q = (-(dx * dx) / (s * s)).exp();
            let mut step = (-(dx / (s * s)) * (x0 - m + 0.5 * dx)).exp();
            for node in pdf.iter_mut().take(hi + 1).skip(lo) {
                *node += amp * g;
                g *= step;
                step *= q;
            }
        }
        let log_pdf = pdf.into_iter().map(|p| p.max(1e-300).ln()).collect();
        DensityGrid { log_pdf }
    }

    /// Interpolated log-density at `x` (clamped to `[0, 1]`).
    #[inline]
    pub fn log_pdf(&self, x: f64) -> f64 {
        let bins = (self.log_pdf.len() - 1) as f64;
        let pos = (x.clamp(0.0, 1.0)) * bins;
        let j = (pos as usize).min(self.log_pdf.len() - 2);
        let frac = pos - j as f64;
        self.log_pdf[j] * (1.0 - frac) + self.log_pdf[j + 1] * frac
    }

    /// Batched lookup: `out[i] = log_pdf(points[i])`.
    pub fn log_pdf_many(&self, points: &[f64], out: &mut [f64]) {
        assert_eq!(points.len(), out.len());
        for (&x, o) in points.iter().zip(out.iter_mut()) {
            *o = self.log_pdf(x);
        }
    }
}

/// Standard-normal PDF.
#[inline]
pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard-normal CDF via erf (Abramowitz-Stegun 7.1.26, |err| < 1.5e-7
/// — far below the noise floor of any acquisition decision).
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Error function approximation.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn cholesky_known() {
        // A = [[4,2],[2,3]] -> L = [[2,0],[1,sqrt(2)]]
        let a = Mat::from_rows(vec![vec![4.0, 2.0], vec![2.0, 3.0]]);
        let c = cholesky(&a).unwrap();
        assert!((c.l.at(0, 0) - 2.0).abs() < 1e-12);
        assert!((c.l.at(1, 0) - 1.0).abs() < 1e-12);
        assert!((c.l.at(1, 1) - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(c.l.at(0, 1), 0.0);
    }

    #[test]
    fn solve_recovers_x() {
        let a = Mat::from_rows(vec![
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let c = cholesky(&a).unwrap();
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.matvec(&x_true);
        let x = c.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10, "{x:?}");
        }
    }

    #[test]
    fn non_spd_rejected() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn log_det_matches() {
        let a = Mat::from_rows(vec![vec![4.0, 0.0], vec![0.0, 9.0]]);
        let c = cholesky(&a).unwrap();
        assert!((c.log_det() - (36f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn prop_solve_random_spd() {
        prop::check(60, |g| {
            let n = g.usize(1, 8);
            // Build SPD as B Bᵀ + n·I.
            let mut b = Mat::zeros(n, n);
            for v in b.data.iter_mut() {
                *v = g.f64(-1.0, 1.0);
            }
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += b.at(i, k) * b.at(j, k);
                    }
                    *a.at_mut(i, j) = s + if i == j { n as f64 } else { 0.0 };
                }
            }
            let x_true: Vec<f64> = (0..n).map(|_| g.f64(-3.0, 3.0)).collect();
            let rhs = a.matvec(&x_true);
            let c = cholesky(&a).map_err(|e| e.to_string())?;
            let x = c.solve(&rhs);
            let err: f64 = x
                .iter()
                .zip(&x_true)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            prop::assert_holds(err < 1e-8, format!("max err {err}"))
        });
    }

    #[test]
    fn norm_cdf_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((norm_cdf(-1.96) - 0.025).abs() < 1e-3);
        assert!(norm_cdf(8.0) > 0.999999);
    }

    #[test]
    fn trunc_mixture_matches_naive() {
        prop::check(60, |g| {
            let n = g.usize(1, 12);
            let mus: Vec<f64> = (0..n).map(|_| g.f64(0.0, 1.0)).collect();
            let sigmas: Vec<f64> = (0..n).map(|_| g.f64(0.02, 0.5)).collect();
            let norms: Vec<f64> = (0..n).map(|_| g.f64(0.5, 1.0)).collect();
            let w = 1.0 / (n as f64 + 1.0);
            let x = g.f64(0.0, 1.0);
            let naive = {
                let mut acc = w;
                for i in 0..n {
                    let t = (x - mus[i]) / sigmas[i];
                    acc += w * (-0.5 * t * t).exp()
                        / ((2.0 * std::f64::consts::PI).sqrt() * sigmas[i])
                        / norms[i];
                }
                acc.ln()
            };
            let fast = trunc_mixture_log_pdf(x, &mus, &sigmas, &norms, w);
            prop::assert_holds((fast - naive).abs() < 1e-12, format!("{fast} vs {naive}"))
        });
    }

    #[test]
    fn trunc_mixture_many_is_bit_identical_to_scalar() {
        // The batched (component-outer) evaluation must agree with the
        // scalar routine to the last bit — TPE's cached fits rely on
        // the suggestion stream not shifting under the layout change.
        prop::check(60, |g| {
            let n = g.usize(0, 200);
            let mus: Vec<f64> = (0..n).map(|_| g.f64(0.0, 1.0)).collect();
            let sigmas: Vec<f64> = (0..n).map(|_| g.f64(0.01, 1.0)).collect();
            let norms: Vec<f64> = (0..n).map(|_| g.f64(0.5, 1.0)).collect();
            let w = 1.0 / (n as f64 + 1.0);
            let points: Vec<f64> = (0..g.usize(1, 32)).map(|_| g.f64(0.0, 1.0)).collect();
            let mut out = vec![0.0; points.len()];
            trunc_mixture_log_pdf_many(&points, &mus, &sigmas, &norms, w, &mut out);
            for (&x, &batched) in points.iter().zip(&out) {
                let scalar = trunc_mixture_log_pdf(x, &mus, &sigmas, &norms, w);
                if scalar.to_bits() != batched.to_bits() {
                    return Err(format!("x={x}: scalar {scalar} != batched {batched}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn density_grid_approximates_exact_log_pdf() {
        prop::check(40, |g| {
            let n = g.usize(1, 30);
            let mus: Vec<f64> = (0..n).map(|_| g.f64(0.0, 1.0)).collect();
            let sigmas: Vec<f64> = (0..n).map(|_| g.f64(0.01, 0.3)).collect();
            let norms: Vec<f64> = vec![1.0; n];
            let w = 1.0 / (n as f64 + 1.0);
            let grid = DensityGrid::from_trunc_mixture(&mus, &sigmas, &norms, w, 4096);
            let x = g.f64(0.0, 1.0);
            let exact = trunc_mixture_log_pdf(x, &mus, &sigmas, &norms, w);
            let approx = grid.log_pdf(x);
            prop::assert_holds(
                (approx - exact).abs() < 2e-2,
                format!("x={x} approx={approx} exact={exact}"),
            )
        });
    }

    #[test]
    fn density_grid_exact_at_nodes() {
        // At grid nodes the tabulated value must equal the exact mixture
        // log-density (the recurrence is exact up to float round-off).
        let mus = [0.2, 0.5, 0.9];
        let sigmas = [0.05, 0.1, 0.2];
        let norms = [0.98, 0.99, 0.97];
        let w = 0.25;
        let bins = 256;
        let grid = DensityGrid::from_trunc_mixture(&mus, &sigmas, &norms, w, bins);
        for j in 0..=bins {
            let x = j as f64 / bins as f64;
            let exact = trunc_mixture_log_pdf(x, &mus, &sigmas, &norms, w);
            let got = grid.log_pdf(x);
            // ±8σ truncation plus recurrence round-off.
            assert!((got - exact).abs() < 1e-6, "node {j}: {got} vs {exact}");
        }
    }

    #[test]
    fn density_grid_prior_only_is_flat() {
        let grid = DensityGrid::from_trunc_mixture(&[], &[], &[], 0.5, 64);
        for x in [0.0, 0.25, 0.333, 0.999, 1.0] {
            assert!((grid.log_pdf(x) - 0.5f64.ln()).abs() < 1e-12);
        }
    }

    #[test]
    fn erf_symmetry() {
        prop::check(100, |g| {
            let x = g.f64(-4.0, 4.0);
            prop::assert_holds((erf(x) + erf(-x)).abs() < 1e-12, format!("x={x}"))
        });
    }
}

//! JSON serialization with full string escaping.

use super::Value;

/// Serialize compactly into `out`.
pub fn write(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(x, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write(x, out);
            }
            out.push('}');
        }
    }
}

/// Serialize with 2-space indentation.
pub fn write_pretty(v: &Value, out: &mut String, indent: usize) {
    match v {
        Value::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(out, indent + 1);
                write_pretty(x, out, indent + 1);
            }
            out.push('\n');
            push_indent(out, indent);
            out.push(']');
        }
        Value::Obj(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(out, indent + 1);
                write_str(k, out);
                out.push_str(": ");
                write_pretty(x, out, indent + 1);
            }
            out.push('\n');
            push_indent(out, indent);
            out.push('}');
        }
        other => write(other, out),
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

/// Append a JSON number (or `null` for non-finite values) to `out`.
/// Public so pre-rendered fragments (materialized views, pages) can be
/// streamed into strings without building `Value` trees.
pub fn write_json_num(n: f64, out: &mut String) {
    write_num(n, out)
}

/// Append a JSON string literal (quoted, escaped) to `out`.
pub fn write_json_str(s: &str, out: &mut String) {
    write_str(s, out)
}

/// JSON numbers cannot be NaN/Inf; encode those as null (matching the
/// common python `json` practice the paper's stack would hit via
/// `allow_nan=False` handling — we choose null rather than erroring so a
/// diverged trial loss remains reportable).
fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null");
        return;
    }
    if n == n.trunc() && n.abs() < 1e15 {
        // Integral: print without the trailing ".0" so ids serialize
        // as integers.
        let i = n as i64;
        out.push_str(&i.to_string());
    } else {
        // Shortest roundtrip formatting from the std float printer.
        let s = format!("{n}");
        // `{}` on f64 never prints NaN/inf here (checked) and always
        // round-trips.
        out.push_str(&s);
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use crate::json::{parse, Value};

    #[test]
    fn integers_without_decimal_point() {
        assert_eq!(Value::Num(42.0).to_string(), "42");
        assert_eq!(Value::Num(-3.0).to_string(), "-3");
        assert_eq!(Value::Num(0.0).to_string(), "0");
    }

    #[test]
    fn floats_roundtrip() {
        for x in [0.1, -2.5e-8, 1.0 / 3.0, 1e100, f64::MIN_POSITIVE] {
            let s = Value::Num(x).to_string();
            assert_eq!(parse(&s).unwrap().as_f64(), Some(x), "s={s}");
        }
    }

    #[test]
    fn nan_inf_to_null() {
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn control_chars_escaped() {
        let s = Value::Str("\u{0001}\n\"x\\".into()).to_string();
        assert_eq!(s, "\"\\u0001\\n\\\"x\\\\\"");
        assert_eq!(parse(&s).unwrap().as_str(), Some("\u{0001}\n\"x\\"));
    }

    #[test]
    fn nested_compact() {
        let mut o = Value::obj();
        o.set("a", vec![1i64, 2]).set("b", "x");
        assert_eq!(Value::Obj(o).to_string(), r#"{"a":[1,2],"b":"x"}"#);
    }
}

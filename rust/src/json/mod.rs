//! JSON codec substrate.
//!
//! The HOPAAS wire protocol is JSON over HTTP (the paper's stack is
//! FastAPI/pydantic). `serde_json` is unavailable in this offline build,
//! so this module provides a complete RFC 8259 implementation: a
//! [`Value`] model, a recursive-descent [`parse`] with depth limiting,
//! and a serializer with escaping. Object key order is preserved
//! (insertion order) so canonical study hashing is deterministic.

mod parse;
pub mod write;

pub use parse::{parse, ParseError};
pub use write::{write_json_num, write_json_str};

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order via a parallel index,
/// which keeps serialization stable for canonical hashing.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Obj),
}

/// Insertion-ordered string→Value map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Obj {
    keys: Vec<String>,
    map: BTreeMap<String, Value>,
}

impl Obj {
    pub fn new() -> Self {
        Obj::default()
    }

    /// Insert or replace; preserves the original position on replace.
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Value>) -> &mut Self {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value.into());
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn remove(&mut self, key: &str) -> Option<Value> {
        if let Some(v) = self.map.remove(key) {
            self.keys.retain(|k| k != key);
            Some(v)
        } else {
            None
        }
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Iterate in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Value)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }

    /// Keys in insertion order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.keys.iter().map(|k| k.as_str())
    }
}

impl Value {
    /// Build an object value fluently: `Value::obj().set("a", 1)`.
    pub fn obj() -> Obj {
        Obj::new()
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&Obj> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// `value["key"]`-style access returning Null on miss.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access returning Null on miss.
    pub fn at(&self, idx: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        write::write(self, &mut s);
        s
    }

    /// Serialize with 2-space indentation (dashboard/debug output).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        write::write_pretty(self, &mut s, 0);
        s
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<Obj> for Value {
    fn from(o: Obj) -> Self {
        Value::Obj(o)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i32> for Value {
    fn from(n: i32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl<T: Into<Value>> From<Option<T>> for Value {
    fn from(v: Option<T>) -> Self {
        match v {
            Some(x) => x.into(),
            None => Value::Null,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn obj_preserves_insertion_order() {
        let mut o = Obj::new();
        o.set("z", 1).set("a", 2).set("m", 3);
        let keys: Vec<_> = o.keys().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
        assert_eq!(o.to_owned().len(), 3);
    }

    #[test]
    fn obj_replace_keeps_position() {
        let mut o = Obj::new();
        o.set("a", 1).set("b", 2).set("a", 9);
        let keys: Vec<_> = o.keys().collect();
        assert_eq!(keys, vec!["a", "b"]);
        assert_eq!(o.get("a").unwrap().as_i64(), Some(9));
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": [1, "two", true, null], "b": {"c": 2.5}}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_i64(), Some(1));
        assert_eq!(v.get("a").at(1).as_str(), Some("two"));
        assert_eq!(v.get("a").at(2).as_bool(), Some(true));
        assert!(v.get("a").at(3).is_null());
        assert_eq!(v.get("b").get("c").as_f64(), Some(2.5));
        assert!(v.get("missing").is_null());
        assert!(v.at(0).is_null());
    }

    #[test]
    fn roundtrip_property() {
        fn gen_value(g: &mut prop::Gen, depth: usize) -> Value {
            let choices = if depth == 0 { 4 } else { 6 };
            match g.rng().below(choices) {
                0 => Value::Null,
                1 => Value::Bool(g.bool()),
                2 => Value::Num((g.f64_any() * 1e6).round() / 1e6),
                3 => Value::Str(g.string(12)),
                4 => Value::Arr(g.vec(0..=4, |g| gen_value(g, depth - 1))),
                _ => {
                    let mut o = Obj::new();
                    for _ in 0..g.usize(0, 4) {
                        o.set(g.ident(8), gen_value(g, depth - 1));
                    }
                    Value::Obj(o)
                }
            }
        }
        prop::check(300, |g| {
            let v = gen_value(g, 3);
            let s = v.to_string();
            let back = parse(&s).map_err(|e| format!("parse failed on {s}: {e}"))?;
            prop::assert_holds(back == v, format!("roundtrip mismatch: {s}"))
        });
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        let p = v.to_pretty();
        assert_eq!(parse(&p).unwrap(), v);
        assert!(p.contains('\n'));
    }
}

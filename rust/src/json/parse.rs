//! Recursive-descent JSON parser (RFC 8259) with depth limiting.

use super::{Obj, Value};
use std::fmt;

/// Maximum nesting depth accepted — guards the service against
/// stack-exhaustion via deeply nested request bodies.
const MAX_DEPTH: usize = 128;

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value(0)?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut o = Obj::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(o));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value(depth + 1)?;
            o.set(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value(depth + 1)?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("invalid surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                            };
                            s.push(c);
                            // hex4 leaves i past the 4 digits; continue
                            // without the shared `i += 1` below.
                            continue;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' || c < 0x20 {
                            break;
                        }
                        self.i += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.b[self.i];
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a' + 10) as u32,
                b'A'..=b'F' => (c - b'A' + 10) as u32,
                _ => return Err(self.err("invalid hex digit")),
            };
            v = v * 16 + d;
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        // Integer part: a leading 0 must not be followed by digits.
        match self.peek() {
            Some(b'0') => {
                self.i += 1;
                if matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    return Err(self.err("leading zero"));
                }
            }
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.i += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        // lint:allow(unwrap_boundary): the slice was just scanned as ASCII digits/signs — an internal invariant, not an input boundary.
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let n: f64 = text.parse().map_err(|_| self.err("number out of range"))?;
        if !n.is_finite() {
            return Err(self.err("number overflows f64"));
        }
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_values() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Value::Num(-50.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Obj::new()));
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" \t\n{ \"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").at(1).as_i64(), Some(2));
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\c\/d\n\tAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c/d\n\tAé"));
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn lone_surrogate_rejected() {
        assert!(parse(r#""\ud83d""#).is_err());
        assert!(parse(r#""\ude00""#).is_err());
    }

    #[test]
    fn errors() {
        for bad in [
            "", "tru", "01", "1.", "1e", "[1,", "{\"a\"}", "{\"a\":1,}", "\"abc",
            "[1]x", "nan", "+1", "'a'", "{a:1}",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_rejected() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(64) + &"]".repeat(64);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn control_char_rejected() {
        assert!(parse("\"a\u{0001}b\"").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo 😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 😀"));
    }

    #[test]
    fn big_numbers() {
        assert!(parse("1e400").is_err());
        assert_eq!(parse("123456789012345").unwrap().as_i64(), Some(123456789012345));
    }
}

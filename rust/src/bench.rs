//! Benchmark harness utilities (criterion is unavailable offline — see
//! DESIGN.md §3). Provides warmup + repeat timing with exact quantiles
//! and aligned table output; every `rust/benches/*.rs` binary
//! (`harness = false`) builds on this.

use std::time::{Duration, Instant};

/// Latency sample set with exact quantiles.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Samples {
        Samples::default()
    }

    pub fn push(&mut self, seconds: f64) {
        self.xs.push(seconds);
    }

    /// Time one call and record it.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.push(t0.elapsed().as_secs_f64());
        out
    }

    pub fn merge(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn quantile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(f64::total_cmp);
        s[((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize]
    }

    pub fn total(&self) -> f64 {
        self.xs.iter().sum()
    }

    /// "p50/p95/p99" formatted in adaptive units.
    pub fn summary(&self) -> String {
        format!(
            "{} / {} / {}",
            fmt_duration(self.quantile(0.5)),
            fmt_duration(self.quantile(0.95)),
            fmt_duration(self.quantile(0.99)),
        )
    }
}

/// Human duration formatting with µs/ms/s autoscaling.
pub fn fmt_duration(seconds: f64) -> String {
    if seconds < 1e-3 {
        format!("{:.1}µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.2}ms", seconds * 1e3)
    } else {
        format!("{:.2}s", seconds)
    }
}

/// Run `f` for `warmup` unrecorded and `iters` recorded iterations.
pub fn bench(warmup: usize, iters: usize, mut f: impl FnMut()) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        s.time(&mut f);
    }
    s
}

/// Mean ± std over a set of scalar outcomes (e.g. best-so-far values).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str], widths: &[usize]) -> Table {
        let t = Table { widths: widths.to_vec() };
        t.row(headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + widths.len()));
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let line: Vec<String> = cells
            .iter()
            .zip(&self.widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect();
        println!("{}", line.join(" "));
    }
}

/// Wall-clock a closure.
pub fn wall<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_exact() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 100.0);
        assert!((s.quantile(0.5) - 50.0).abs() <= 1.0);
        assert!((s.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
    }

    #[test]
    fn bench_counts() {
        let mut n = 0;
        let s = bench(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn mean_std_basic() {
        let (m, sd) = mean_std(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert_eq!(sd, 1.0);
    }
}

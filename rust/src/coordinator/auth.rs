//! API-token authentication.
//!
//! The paper's service authenticates the Table 1 APIs with an API token
//! carried in the request path (`/api/ask/{token}`); tokens are issued
//! through the web UI after an OAuth2 login, each with "a validity period
//! defined at generation" and revocable at any time (paper §3). The
//! client-visible contract is exactly reproduced here with self-contained
//! HMAC-SHA256 tokens:
//!
//! ```text
//! token := hex(payload-json) "." hex(HMAC-SHA256(secret, payload-json))
//! payload := {"uid": ..., "user": ..., "iat": ..., "exp": ...}
//! ```
//!
//! Validation checks the signature, the expiry against the server clock,
//! and a revocation list keyed by token id. No identity provider is
//! needed on the validation path — matching how NGINX+FastAPI only ever
//! see the bearer token, not the IAM handshake.

use crate::json::Value;
use crate::sync::MutexExt;
use hmac::{Hmac, Mac};
use sha2::Sha256;
use std::collections::HashSet;
use std::sync::Mutex;

type HmacSha256 = Hmac<Sha256>;

/// Why a token was rejected.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum AuthError {
    #[error("malformed token")]
    Malformed,
    #[error("bad signature")]
    BadSignature,
    #[error("token expired")]
    Expired,
    #[error("token revoked")]
    Revoked,
}

/// A validated token's claims.
#[derive(Debug, Clone, PartialEq)]
pub struct Claims {
    pub uid: u64,
    pub user: String,
    pub issued_at: f64,
    pub expires_at: f64,
}

impl Claims {
    /// The tenant identity behind this token — the quota-policy key for
    /// per-tenant admission limits. Tokens are per-user ("issued through
    /// the web UI after an OAuth2 login", §3), so the `user` claim *is*
    /// the tenant: every token carrying the same user shares one budget,
    /// and the per-token `uid` is deliberately not used (re-minting a
    /// token for the same user cannot reset headroom). Empty-user tokens
    /// map to no tenant and are never tenant-limited.
    ///
    /// Tenant isolation is exactly as strong as the *issuance* policy:
    /// this reproduction's `POST /api/token` mints tokens for any
    /// requested user with no credential (the paper's OAuth2 web flow is
    /// out of scope), so a caller who can reach the token endpoint can
    /// mint fresh identities and sidestep per-tenant caps. Production
    /// deployments must front token issuance with real authentication
    /// for tenant quotas to be an enforcement boundary rather than an
    /// accounting convention.
    pub fn tenant(&self) -> Option<&str> {
        if self.user.is_empty() {
            None
        } else {
            Some(&self.user)
        }
    }
}

/// Token issuer + validator.
pub struct TokenService {
    secret: Vec<u8>,
    revoked: Mutex<HashSet<u64>>,
    next_uid: Mutex<u64>,
}

fn hex_encode(b: &[u8]) -> String {
    b.iter().map(|x| format!("{x:02x}")).collect()
}

fn hex_decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).ok())
        .collect()
}

impl TokenService {
    pub fn new(secret: &[u8]) -> TokenService {
        TokenService {
            secret: secret.to_vec(),
            revoked: Mutex::new(HashSet::new()),
            next_uid: Mutex::new(1),
        }
    }

    fn sign(&self, payload: &[u8]) -> String {
        let mut mac = HmacSha256::new_from_slice(&self.secret).expect("hmac key");
        mac.update(payload);
        hex_encode(&mac.finalize().into_bytes())
    }

    /// Issue a token for `user` valid for `ttl` seconds from `now`
    /// (server-relative seconds, as everywhere in the coordinator).
    pub fn issue(&self, user: &str, now: f64, ttl: f64) -> String {
        let uid = {
            let mut g = self.next_uid.lock_safe();
            let u = *g;
            *g += 1;
            u
        };
        let mut o = Value::obj();
        o.set("uid", uid)
            .set("user", user)
            .set("iat", now)
            .set("exp", now + ttl.max(0.0));
        let payload = Value::Obj(o).to_string().into_bytes();
        format!("{}.{}", hex_encode(&payload), self.sign(&payload))
    }

    /// Validate a token string at time `now`.
    pub fn validate(&self, token: &str, now: f64) -> Result<Claims, AuthError> {
        let (payload_hex, sig_hex) = token.split_once('.').ok_or(AuthError::Malformed)?;
        let payload = hex_decode(payload_hex).ok_or(AuthError::Malformed)?;
        // Constant-time-ish compare via re-HMAC of both sides.
        let expect = self.sign(&payload);
        if !constant_time_eq(expect.as_bytes(), sig_hex.as_bytes()) {
            return Err(AuthError::BadSignature);
        }
        let text = std::str::from_utf8(&payload).map_err(|_| AuthError::Malformed)?;
        let v = crate::json::parse(text).map_err(|_| AuthError::Malformed)?;
        let claims = Claims {
            uid: v.get("uid").as_u64().ok_or(AuthError::Malformed)?,
            user: v.get("user").as_str().unwrap_or("").to_string(),
            issued_at: v.get("iat").as_f64().unwrap_or(0.0),
            expires_at: v.get("exp").as_f64().ok_or(AuthError::Malformed)?,
        };
        if now > claims.expires_at {
            return Err(AuthError::Expired);
        }
        if self.revoked.lock_safe().contains(&claims.uid) {
            return Err(AuthError::Revoked);
        }
        Ok(claims)
    }

    /// Revoke a token by id ("can be revoked at any time", §3).
    pub fn revoke(&self, uid: u64) {
        self.revoked.lock_safe().insert(uid);
    }

    /// Number of revoked tokens (metrics).
    pub fn revoked_count(&self) -> usize {
        self.revoked.lock_safe().len()
    }
}

fn constant_time_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b) {
        diff |= x ^ y;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> TokenService {
        TokenService::new(b"test-secret")
    }

    #[test]
    fn issue_validate_roundtrip() {
        let s = svc();
        let tok = s.issue("alice", 100.0, 3600.0);
        let c = s.validate(&tok, 200.0).unwrap();
        assert_eq!(c.user, "alice");
        assert_eq!(c.issued_at, 100.0);
        assert_eq!(c.expires_at, 3700.0);
    }

    #[test]
    fn expired_rejected() {
        let s = svc();
        let tok = s.issue("bob", 0.0, 10.0);
        assert_eq!(s.validate(&tok, 5.0).map(|c| c.user).unwrap(), "bob");
        assert_eq!(s.validate(&tok, 11.0), Err(AuthError::Expired));
    }

    #[test]
    fn revoked_rejected() {
        let s = svc();
        let tok = s.issue("carol", 0.0, 1e6);
        let c = s.validate(&tok, 1.0).unwrap();
        s.revoke(c.uid);
        assert_eq!(s.validate(&tok, 2.0), Err(AuthError::Revoked));
        assert_eq!(s.revoked_count(), 1);
    }

    #[test]
    fn tampered_rejected() {
        let s = svc();
        let tok = s.issue("dave", 0.0, 1e6);
        // Flip one hex char of the payload.
        let mut chars: Vec<char> = tok.chars().collect();
        chars[0] = if chars[0] == 'a' { 'b' } else { 'a' };
        let bad: String = chars.into_iter().collect();
        assert!(matches!(
            s.validate(&bad, 1.0),
            Err(AuthError::BadSignature) | Err(AuthError::Malformed)
        ));
    }

    #[test]
    fn wrong_secret_rejected() {
        let s1 = TokenService::new(b"one");
        let s2 = TokenService::new(b"two");
        let tok = s1.issue("eve", 0.0, 1e6);
        assert_eq!(s2.validate(&tok, 1.0), Err(AuthError::BadSignature));
    }

    #[test]
    fn malformed_rejected() {
        let s = svc();
        for bad in ["", "nodot", "zz.zz", "abc.def", "0g00.ffff"] {
            assert!(s.validate(bad, 0.0).is_err(), "{bad}");
        }
    }

    #[test]
    fn tokens_are_path_safe() {
        let s = svc();
        let tok = s.issue("x", 0.0, 1.0);
        assert!(tok.chars().all(|c| c.is_ascii_hexdigit() || c == '.'));
    }

    #[test]
    fn tenant_is_the_user_claim_across_tokens() {
        let s = svc();
        let c1 = s.validate(&s.issue("alice", 0.0, 10.0), 0.0).unwrap();
        let c2 = s.validate(&s.issue("alice", 0.0, 10.0), 0.0).unwrap();
        // Two distinct tokens (distinct uids) share one tenant budget.
        assert_ne!(c1.uid, c2.uid);
        assert_eq!(c1.tenant(), Some("alice"));
        assert_eq!(c1.tenant(), c2.tenant());
        let anon = s.validate(&s.issue("", 0.0, 10.0), 0.0).unwrap();
        assert_eq!(anon.tenant(), None, "empty user is tenant-less");
    }

    #[test]
    fn uids_unique() {
        let s = svc();
        let c1 = s.validate(&s.issue("u", 0.0, 10.0), 0.0).unwrap();
        let c2 = s.validate(&s.issue("u", 0.0, 10.0), 0.0).unwrap();
        assert_ne!(c1.uid, c2.uid);
    }
}

//! Materialized read views and the live trial feed.
//!
//! The read path the dashboard hits must not contend on the shard locks
//! the ask/tell hot path needs. This module keeps, per study, an
//! **epoch-stamped materialized view**: a pre-rendered copy of the study
//! summary and of every trial's summary fragment, swapped atomically
//! behind an `RwLock<Arc<..>>` so readers only ever clone an `Arc` —
//! no shard lock, no JSON tree construction, no per-request allocation
//! beyond the final page string.
//!
//! **Epoch-stamping rule.** A view is published *under the shard lock,
//! immediately after the in-memory apply of an acknowledged mutation*
//! (the same critical section that bumps the tell-epoch via
//! `StudyRuntime::note_scored`). The published view therefore contains
//! exactly the trials of some acknowledged prefix of the write stream —
//! never a torn mid-batch state (batched inserts publish once, after the
//! whole batch applied). The stamp is the study's tell-epoch at publish
//! time; under synchronous publication the staleness bound is 0 epochs,
//! and `hopaas_view_staleness_epochs` exports the observed maximum so a
//! future asynchronous refresher stays honest.
//!
//! **Trial feed.** Every terminal transition (tell / prune / fail)
//! appends a [`StudyEvent`] to a per-study append-only log; the log
//! length is the study's *watermark*. `GET /events?since=W` returns all
//! events with `seq > W`, or parks the reader (see the parked-reader
//! registry in `http::server`) on the engine-global [`Notify`] until the
//! watermark advances or the poll timeout expires.
//!
//! Views rebuild deterministically through recovery replay: the rebuild
//! walks recovered trials in slot order and reconstructs the event log
//! from terminal trials ordered by `(finished_at, trial_id)`.

use super::metrics::Metrics;
use super::space::Direction;
use super::study::Study;
use super::trial::{Trial, TrialState};
use crate::http::Notify;
use crate::json::write::{write_json_num, write_json_str};
use crate::obs::{self, Stage};
use crate::sync::{MutexExt, RwLockExt};
use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Terminal transition kinds carried by the trial feed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Completed,
    Pruned,
    Failed,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Completed => "completed",
            EventKind::Pruned => "pruned",
            EventKind::Failed => "failed",
        }
    }
}

/// One trial-feed entry. `seq` is 1-based and dense per study; the
/// study's watermark is the seq of its latest event.
pub struct StudyEvent {
    pub seq: u64,
    pub trial_id: u64,
    pub number: u64,
    pub kind: EventKind,
    pub value: Option<f64>,
    pub at: f64,
    /// Pre-rendered JSON fragment (an object, no trailing comma).
    pub json: Arc<str>,
}

impl StudyEvent {
    fn render(seq: u64, trial: &Trial, kind: EventKind) -> StudyEvent {
        let value = match kind {
            EventKind::Completed => trial.value.or_else(|| {
                // Multi-objective completion: no scalar value; the feed
                // carries the first objective as a progress hint.
                trial.values.as_ref().and_then(|v| v.first().copied())
            }),
            EventKind::Pruned => trial.last_intermediate().map(|(_, v)| v),
            EventKind::Failed => None,
        };
        let at = trial.finished_at.unwrap_or(trial.started_at);
        let mut s = String::with_capacity(96);
        s.push_str("{\"seq\":");
        write_json_num(seq as f64, &mut s);
        s.push_str(",\"trial_id\":");
        write_json_num(trial.id as f64, &mut s);
        s.push_str(",\"number\":");
        write_json_num(trial.number as f64, &mut s);
        s.push_str(",\"kind\":");
        write_json_str(kind.as_str(), &mut s);
        s.push_str(",\"value\":");
        match value {
            Some(v) => write_json_num(v, &mut s),
            None => s.push_str("null"),
        }
        s.push_str(",\"at\":");
        write_json_num(at, &mut s);
        s.push('}');
        StudyEvent { seq, trial_id: trial.id, number: trial.number, kind, value, at, json: s.into() }
    }
}

/// Immutable per-trial view entry: the fields pagination filters on,
/// plus the pre-rendered summary fragment pages concatenate.
pub struct TrialLite {
    pub id: u64,
    pub number: u64,
    pub state: TrialState,
    pub value: Option<f64>,
    /// Pre-rendered JSON summary (id/number/state/params/value/values/
    /// started_at/finished_at/node/n_steps/last_step/last_value).
    pub json: Arc<str>,
}

impl TrialLite {
    fn render(t: &Trial) -> Arc<TrialLite> {
        let mut s = String::with_capacity(192);
        s.push_str("{\"id\":");
        write_json_num(t.id as f64, &mut s);
        s.push_str(",\"number\":");
        write_json_num(t.number as f64, &mut s);
        s.push_str(",\"state\":");
        write_json_str(t.state.as_str(), &mut s);
        s.push_str(",\"params\":{");
        for (i, (k, v)) in t.params.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            write_json_str(k, &mut s);
            s.push(':');
            crate::json::write::write(v, &mut s);
        }
        s.push_str("},\"value\":");
        match t.value {
            Some(v) => write_json_num(v, &mut s),
            None => s.push_str("null"),
        }
        s.push_str(",\"values\":");
        match &t.values {
            Some(vs) => {
                s.push('[');
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    write_json_num(*v, &mut s);
                }
                s.push(']');
            }
            None => s.push_str("null"),
        }
        s.push_str(",\"started_at\":");
        write_json_num(t.started_at, &mut s);
        s.push_str(",\"finished_at\":");
        match t.finished_at {
            Some(v) => write_json_num(v, &mut s),
            None => s.push_str("null"),
        }
        s.push_str(",\"node\":");
        match &t.node {
            Some(n) => write_json_str(n, &mut s),
            None => s.push_str("null"),
        }
        s.push_str(",\"n_steps\":");
        write_json_num(t.intermediate.len() as f64, &mut s);
        match t.last_intermediate() {
            Some((step, v)) => {
                s.push_str(",\"last_step\":");
                write_json_num(step as f64, &mut s);
                s.push_str(",\"last_value\":");
                write_json_num(v, &mut s);
            }
            None => s.push_str(",\"last_step\":null,\"last_value\":null"),
        }
        s.push('}');
        Arc::new(TrialLite { id: t.id, number: t.number, state: t.state, value: t.value, json: s.into() })
    }
}

/// An immutable, epoch-stamped snapshot of one study. Readers clone the
/// `Arc` and serve any number of pages from it without further
/// coordination; the trial vector is append-only across snapshots
/// (slot `i` always names the same trial), which is what makes cursors
/// stable across epochs and compactions.
pub struct StudyView {
    pub study_id: u64,
    /// Tell-epoch at publish time.
    pub epoch: u64,
    /// Pre-rendered study summary object.
    pub summary: Arc<str>,
    /// `(value, trial_id)` of the incumbent (single-objective only).
    pub best: Option<(f64, u64)>,
    pub trials: Arc<Vec<Arc<TrialLite>>>,
}

/// Writer-side incremental state: counts and best are maintained by
/// delta on each transition, so publishing is O(changed trials), not
/// O(study size). The trial vector is shared with published snapshots
/// via `Arc::make_mut` (copy-on-write only while a reader still holds
/// the previous snapshot).
struct ViewBuilder {
    /// `"id":...,"key":...,...` — the immutable definition fields,
    /// rendered once at study creation (no surrounding braces).
    static_fields: String,
    direction: Direction,
    is_mo: bool,
    created_at: f64,
    n_running: usize,
    n_completed: usize,
    n_pruned: usize,
    n_failed: usize,
    best: Option<(f64, u64)>,
    trials: Arc<Vec<Arc<TrialLite>>>,
}

impl ViewBuilder {
    fn new(study: &Study) -> ViewBuilder {
        let mut s = String::with_capacity(256);
        s.push_str("\"id\":");
        write_json_num(study.id as f64, &mut s);
        s.push_str(",\"key\":");
        write_json_str(&study.key, &mut s);
        s.push_str(",\"name\":");
        write_json_str(&study.def.name, &mut s);
        s.push_str(",\"direction\":");
        write_json_str(study.def.direction.as_str(), &mut s);
        s.push_str(",\"sampler\":");
        crate::json::write::write(&study.def.sampler.to_json(), &mut s);
        s.push_str(",\"pruner\":");
        match &study.def.pruner {
            Some(p) => crate::json::write::write(&p.to_json(), &mut s),
            None => s.push_str("null"),
        }
        s.push_str(",\"properties\":");
        crate::json::write::write(&study.def.space.to_json(), &mut s);
        if let Some(ds) = &study.def.directions {
            s.push_str(",\"directions\":[");
            for (i, d) in ds.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                write_json_str(d.as_str(), &mut s);
            }
            s.push(']');
        }
        ViewBuilder {
            static_fields: s,
            direction: study.def.direction,
            is_mo: study.def.is_mo(),
            created_at: study.created_at,
            n_running: 0,
            n_completed: 0,
            n_pruned: 0,
            n_failed: 0,
            best: None,
            trials: Arc::new(Vec::new()),
        }
    }

    fn count_delta(&mut self, state: TrialState, delta: isize) {
        let slot = match state {
            TrialState::Running => &mut self.n_running,
            TrialState::Completed => &mut self.n_completed,
            TrialState::Pruned => &mut self.n_pruned,
            TrialState::Failed => &mut self.n_failed,
        };
        *slot = slot.saturating_add_signed(delta);
    }

    fn note_completed(&mut self, trial: &Trial) {
        if self.is_mo {
            return; // Pareto ranking is served by the legacy study APIs.
        }
        if let Some(v) = trial.value {
            let better = match self.best {
                None => true,
                Some((b, _)) => self.direction.better(v, b),
            };
            if better {
                self.best = Some((v, trial.id));
            }
        }
    }

    fn summary(&self, epoch: u64) -> Arc<str> {
        let mut s = String::with_capacity(self.static_fields.len() + 192);
        s.push('{');
        s.push_str(&self.static_fields);
        s.push_str(",\"epoch\":");
        write_json_num(epoch as f64, &mut s);
        s.push_str(",\"n_trials\":");
        write_json_num(self.trials.len() as f64, &mut s);
        s.push_str(",\"n_running\":");
        write_json_num(self.n_running as f64, &mut s);
        s.push_str(",\"n_completed\":");
        write_json_num(self.n_completed as f64, &mut s);
        s.push_str(",\"n_pruned\":");
        write_json_num(self.n_pruned as f64, &mut s);
        s.push_str(",\"n_failed\":");
        write_json_num(self.n_failed as f64, &mut s);
        s.push_str(",\"created_at\":");
        write_json_num(self.created_at, &mut s);
        s.push_str(",\"best_value\":");
        match self.best {
            Some((v, _)) => write_json_num(v, &mut s),
            None => s.push_str("null"),
        }
        s.push_str(",\"best_trial\":");
        match self.best {
            Some((_, id)) => write_json_num(id as f64, &mut s),
            None => s.push_str("null"),
        }
        s.push('}');
        s.into()
    }
}

/// Per-study slot: the writer-side builder, the published snapshot, and
/// the event log.
struct StudySlot {
    builder: Mutex<ViewBuilder>,
    view: RwLock<Arc<StudyView>>,
    events: Mutex<Vec<Arc<StudyEvent>>>,
}

/// One page of the trial feed (served by `/api/studies/{id}/events`).
pub struct EventsPage {
    /// The study's current watermark (seq of the latest event).
    pub watermark: u64,
    pub events: Vec<Arc<StudyEvent>>,
}

/// The registry of materialized views, shared between the engine (writer
/// side, called under shard locks) and the HTTP read path.
pub struct ViewRegistry {
    slots: RwLock<HashMap<u64, Arc<StudySlot>>>,
    /// Engine-global feed signal: its generation bumps on every event
    /// append, waking the parked-reader pump.
    signal: Arc<Notify>,
    waiters: AtomicI64,
    metrics: Arc<Metrics>,
}

impl ViewRegistry {
    pub fn new(metrics: Arc<Metrics>) -> ViewRegistry {
        ViewRegistry {
            slots: RwLock::new(HashMap::new()),
            signal: Arc::new(Notify::new()),
            waiters: AtomicI64::new(0),
            metrics,
        }
    }

    /// The feed signal the HTTP server's parked-reader pump waits on.
    pub fn signal(&self) -> Arc<Notify> {
        self.signal.clone()
    }

    /// Track a parked events reader (+1) or its completion (-1).
    pub fn waiter_delta(&self, delta: i64) {
        let now = self.waiters.fetch_add(delta, Ordering::Relaxed) + delta;
        self.metrics.events_waiters.set(now.max(0) as f64);
    }

    pub fn waiters(&self) -> i64 {
        self.waiters.load(Ordering::Relaxed)
    }

    fn slot(&self, study_id: u64) -> Option<Arc<StudySlot>> {
        self.slots.read_safe().get(&study_id).cloned()
    }

    // ----- writer side (engine calls, under the owning shard lock) -----

    /// Register a study and publish its (empty) initial view.
    pub fn on_study_created(&self, study: &Study) {
        let t0 = std::time::Instant::now();
        let builder = ViewBuilder::new(study);
        let view = Arc::new(StudyView {
            study_id: study.id,
            epoch: study.runtime.epoch,
            summary: builder.summary(study.runtime.epoch),
            best: builder.best,
            trials: builder.trials.clone(),
        });
        let slot = Arc::new(StudySlot {
            builder: Mutex::new(builder),
            view: RwLock::new(view),
            events: Mutex::new(Vec::new()),
        });
        self.slots.write_safe().insert(study.id, slot);
        let took = t0.elapsed();
        self.metrics.view_refresh_seconds.observe(took.as_secs_f64());
        obs::stage(Stage::ViewPublish, took);
    }

    /// New trials appended at `start_slot..`. Called once per acknowledged
    /// insert batch, after the whole batch applied in memory — the view
    /// never exposes a torn prefix of a batch.
    pub fn on_trials_inserted(&self, study: &Study, start_slot: usize) {
        let Some(slot) = self.slot(study.id) else { return };
        let t0 = std::time::Instant::now();
        {
            let mut b = slot.builder.lock_safe();
            for t in &study.trials[start_slot..] {
                let lite = TrialLite::render(t);
                b.count_delta(t.state, 1);
                if t.state == TrialState::Completed {
                    b.note_completed(t);
                }
                Arc::make_mut(&mut b.trials).push(lite);
            }
            Self::publish(&slot, &b, study);
        }
        let took = t0.elapsed();
        self.metrics.view_refresh_seconds.observe(took.as_secs_f64());
        obs::stage(Stage::ViewPublish, took);
    }

    /// One existing trial changed (report / tell / prune / fail /
    /// re-assignment). Re-renders that fragment, adjusts counts and best
    /// by delta, publishes, and (for terminal transitions) appends the
    /// feed event and wakes parked readers.
    pub fn on_trial_updated(&self, study: &Study, trial_slot: usize, event: Option<EventKind>) {
        let Some(slot) = self.slot(study.id) else { return };
        let t0 = std::time::Instant::now();
        let trial = &study.trials[trial_slot];
        {
            let mut b = slot.builder.lock_safe();
            if trial_slot >= b.trials.len() {
                // A mutation for a trial the registry never saw
                // inserted; resync the tail defensively, then re-enter
                // so the feed event (if any) is still appended.
                let start = b.trials.len();
                drop(b);
                self.on_trials_inserted(study, start);
                if event.is_some() && trial_slot < study.trials.len() {
                    return self.on_trial_updated(study, trial_slot, event);
                }
                return;
            }
            let old_state = b.trials[trial_slot].state;
            if old_state != trial.state {
                b.count_delta(old_state, -1);
                b.count_delta(trial.state, 1);
            }
            if trial.state == TrialState::Completed {
                b.note_completed(trial);
            }
            Arc::make_mut(&mut b.trials)[trial_slot] = TrialLite::render(trial);
            Self::publish(&slot, &b, study);
        }
        if let Some(kind) = event {
            let mut log = slot.events.lock_safe();
            let seq = log.len() as u64 + 1;
            log.push(Arc::new(StudyEvent::render(seq, trial, kind)));
            drop(log);
            self.signal.notify_all();
        }
        let took = t0.elapsed();
        self.metrics.view_refresh_seconds.observe(took.as_secs_f64());
        obs::stage(Stage::ViewPublish, took);
    }

    fn publish(slot: &StudySlot, b: &ViewBuilder, study: &Study) {
        let view = Arc::new(StudyView {
            study_id: study.id,
            epoch: study.runtime.epoch,
            summary: b.summary(study.runtime.epoch),
            best: b.best,
            trials: b.trials.clone(),
        });
        *slot.view.write_safe() = view;
    }

    /// Rebuild a study's view and event log from recovered state
    /// (deterministic: trials in slot order; events from terminal trials
    /// ordered by `(finished_at, trial_id)`).
    pub fn rebuild_from(&self, study: &Study) {
        self.on_study_created(study);
        self.on_trials_inserted(study, 0);
        let Some(slot) = self.slot(study.id) else { return };
        let mut terminal: Vec<&Trial> =
            study.trials.iter().filter(|t| t.state.is_terminal()).collect();
        terminal.sort_by(|a, b| {
            let ka = (a.finished_at.unwrap_or(a.started_at), a.id);
            let kb = (b.finished_at.unwrap_or(b.started_at), b.id);
            ka.partial_cmp(&kb).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut log = slot.events.lock_safe();
        log.clear();
        for t in terminal {
            let kind = match t.state {
                TrialState::Completed => EventKind::Completed,
                TrialState::Pruned => EventKind::Pruned,
                TrialState::Failed => EventKind::Failed,
                TrialState::Running => continue,
            };
            let seq = log.len() as u64 + 1;
            log.push(Arc::new(StudyEvent::render(seq, t, kind)));
        }
    }

    // ----- reader side (no shard locks, ever) -----

    /// The current snapshot of one study.
    pub fn study_view(&self, study_id: u64) -> Option<Arc<StudyView>> {
        self.slot(study_id).map(|s| s.view.read_safe().clone())
    }

    /// Current snapshots of all studies, ordered by study id.
    pub fn study_views(&self) -> Vec<Arc<StudyView>> {
        let slots = self.slots.read_safe();
        let mut ids: Vec<u64> = slots.keys().copied().collect();
        ids.sort_unstable();
        ids.iter().map(|id| slots[id].view.read_safe().clone()).collect()
    }

    /// View epoch of one study (staleness probes).
    pub fn view_epoch(&self, study_id: u64) -> Option<u64> {
        self.slot(study_id).map(|s| s.view.read_safe().epoch)
    }

    /// The study's current event watermark, or None if unknown.
    pub fn watermark(&self, study_id: u64) -> Option<u64> {
        self.slot(study_id).map(|s| s.events.lock_safe().len() as u64)
    }

    /// Events with `seq > since` (bounded by `limit`), plus the current
    /// watermark. None = unknown study.
    pub fn events_after(&self, study_id: u64, since: u64, limit: usize) -> Option<EventsPage> {
        let slot = self.slot(study_id)?;
        let log = slot.events.lock_safe();
        let watermark = log.len() as u64;
        let start = (since as usize).min(log.len());
        let events: Vec<Arc<StudyEvent>> =
            log[start..].iter().take(limit.max(1)).cloned().collect();
        Some(EventsPage { watermark, events })
    }

    /// Number of registered studies.
    pub fn n_studies(&self) -> usize {
        self.slots.read_safe().len()
    }
}

// ----- cursors -----

/// A pagination cursor: `v1.<epoch>.<index>`. The index addresses a slot
/// in the (append-only) trial vector, so cursors stay valid across
/// epochs and compactions; the epoch records the snapshot the cursor was
/// issued from (diagnostics only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cursor {
    pub epoch: u64,
    pub index: usize,
}

impl Cursor {
    pub fn encode(&self) -> String {
        format!("v1.{}.{}", self.epoch, self.index)
    }

    /// Parse a client-supplied cursor. `Err` carries a message for the
    /// 422 the HTTP layer answers with.
    pub fn decode(s: &str) -> Result<Cursor, String> {
        let rest = s.strip_prefix("v1.").ok_or_else(|| format!("malformed cursor '{s}'"))?;
        let (epoch, index) =
            rest.split_once('.').ok_or_else(|| format!("malformed cursor '{s}'"))?;
        let epoch: u64 =
            epoch.parse().map_err(|_| format!("malformed cursor '{s}'"))?;
        let index: usize =
            index.parse().map_err(|_| format!("malformed cursor '{s}'"))?;
        Ok(Cursor { epoch, index })
    }
}

// ----- page rendering (string concatenation, no Value trees) -----

/// Render one page of a study's trials from a snapshot: slots
/// `cursor.index..`, filtered by `state`, at most `limit` entries.
/// Returns the JSON page body.
pub fn render_trials_page(
    view: &StudyView,
    cursor: Cursor,
    limit: usize,
    state: Option<TrialState>,
) -> String {
    let limit = limit.clamp(1, 10_000);
    let trials = view.trials.as_ref();
    let mut out = String::with_capacity(128 + 160 * limit.min(trials.len()));
    out.push_str("{\"study_id\":");
    write_json_num(view.study_id as f64, &mut out);
    out.push_str(",\"epoch\":");
    write_json_num(view.epoch as f64, &mut out);
    out.push_str(",\"total\":");
    write_json_num(trials.len() as f64, &mut out);
    out.push_str(",\"trials\":[");
    let mut taken = 0usize;
    let mut next = None;
    let mut i = cursor.index.min(trials.len());
    while i < trials.len() {
        let t = &trials[i];
        i += 1;
        if let Some(want) = state {
            if t.state != want {
                continue;
            }
        }
        if taken == limit {
            // One past the page: there is more — resume at this slot.
            next = Some(i - 1);
            break;
        }
        if taken > 0 {
            out.push(',');
        }
        out.push_str(&t.json);
        taken += 1;
    }
    out.push(']');
    if let Some(idx) = next {
        out.push_str(",\"next_cursor\":");
        write_json_str(&Cursor { epoch: view.epoch, index: idx }.encode(), &mut out);
    }
    out.push('}');
    out
}

/// Render one page of the study list (ordered by id, strictly after
/// `after_id`), at most `limit` summaries. The cursor is the last
/// emitted study id.
pub fn render_studies_page(views: &[Arc<StudyView>], after_id: Option<u64>, limit: usize) -> String {
    let limit = limit.clamp(1, 10_000);
    let eligible: Vec<&Arc<StudyView>> = views
        .iter()
        .filter(|v| after_id.map_or(true, |a| v.study_id > a))
        .collect();
    let page = &eligible[..limit.min(eligible.len())];
    let mut out = String::with_capacity(64 + 256 * page.len());
    out.push_str("{\"studies\":[");
    for (i, v) in page.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&v.summary);
    }
    out.push_str("],\"total\":");
    write_json_num(views.len() as f64, &mut out);
    if eligible.len() > page.len() {
        if let Some(last) = page.last() {
            out.push_str(",\"next_cursor\":");
            write_json_str(&last.study_id.to_string(), &mut out);
        }
    }
    out.push('}');
    out
}

/// Render the incumbent-best page for one study snapshot: the best value
/// plus the full trial fragment of the incumbent (null for studies with
/// no completed trial yet, and for multi-objective studies, whose front
/// is served by the legacy pareto API).
pub fn render_best_page(view: &StudyView) -> String {
    let mut out = String::with_capacity(256);
    out.push_str("{\"study_id\":");
    write_json_num(view.study_id as f64, &mut out);
    out.push_str(",\"epoch\":");
    write_json_num(view.epoch as f64, &mut out);
    out.push_str(",\"best_value\":");
    match view.best {
        Some((v, _)) => write_json_num(v, &mut out),
        None => out.push_str("null"),
    }
    out.push_str(",\"best_trial\":");
    match view.best.and_then(|(_, id)| view.trials.iter().find(|t| t.id == id)) {
        Some(t) => out.push_str(&t.json),
        None => out.push_str("null"),
    }
    out.push('}');
    out
}

/// Render one events page.
pub fn render_events_page(study_id: u64, page: &EventsPage) -> String {
    let mut out = String::with_capacity(64 + 96 * page.events.len());
    out.push_str("{\"study_id\":");
    write_json_num(study_id as f64, &mut out);
    out.push_str(",\"watermark\":");
    write_json_num(page.watermark as f64, &mut out);
    out.push_str(",\"events\":[");
    for (i, e) in page.events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&e.json);
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::study::{parse_ask_body, Study};
    use crate::json::parse;

    fn study() -> Study {
        let body = parse(
            r#"{
            "study_name": "v",
            "properties": {"x": {"low": 0.0, "high": 1.0}},
            "direction": "minimize",
            "sampler": {"name": "random"}
        }"#,
        )
        .unwrap();
        Study::new(3, parse_ask_body(&body).unwrap().0, 0.0)
    }

    fn registry() -> ViewRegistry {
        ViewRegistry::new(Arc::new(Metrics::default()))
    }

    fn push_trial(s: &mut Study, id: u64) {
        let n = s.reserve_number();
        s.trials.push(crate::coordinator::trial::Trial::new(
            id,
            n,
            vec![("x".into(), crate::json::Value::Num(0.5))],
            0.0,
            None,
        ));
    }

    #[test]
    fn view_tracks_counts_and_best() {
        let reg = registry();
        let mut s = study();
        reg.on_study_created(&s);
        for id in 0..3 {
            push_trial(&mut s, id);
        }
        reg.on_trials_inserted(&s, 0);
        let v = reg.study_view(3).unwrap();
        assert_eq!(v.trials.len(), 3);
        assert!(v.summary.contains("\"n_running\":3"), "{}", v.summary);

        s.trials[1].complete(0.25, 1.0).unwrap();
        s.note_scored(1, 8);
        reg.on_trial_updated(&s, 1, Some(EventKind::Completed));
        s.trials[0].prune(2.0).unwrap();
        reg.on_trial_updated(&s, 0, Some(EventKind::Pruned));
        let v = reg.study_view(3).unwrap();
        assert_eq!(v.epoch, 1);
        assert!(v.summary.contains("\"n_completed\":1"), "{}", v.summary);
        assert!(v.summary.contains("\"n_pruned\":1"), "{}", v.summary);
        assert!(v.summary.contains("\"best_value\":0.25"), "{}", v.summary);
        assert!(v.summary.contains("\"best_trial\":1"), "{}", v.summary);
        assert_eq!(v.trials[1].state, TrialState::Completed);

        // Feed: two events, in transition order.
        let page = reg.events_after(3, 0, 100).unwrap();
        assert_eq!(page.watermark, 2);
        assert_eq!(page.events[0].kind, EventKind::Completed);
        assert_eq!(page.events[1].kind, EventKind::Pruned);
        assert_eq!(page.events[0].trial_id, 1);
        // since=watermark → empty.
        let page = reg.events_after(3, 2, 100).unwrap();
        assert!(page.events.is_empty());
        assert_eq!(page.watermark, 2);
    }

    #[test]
    fn snapshots_are_immutable_under_later_writes() {
        let reg = registry();
        let mut s = study();
        reg.on_study_created(&s);
        push_trial(&mut s, 0);
        reg.on_trials_inserted(&s, 0);
        let old = reg.study_view(3).unwrap();
        assert_eq!(old.trials.len(), 1);
        push_trial(&mut s, 1);
        reg.on_trials_inserted(&s, 1);
        // The held snapshot did not grow; the fresh one did.
        assert_eq!(old.trials.len(), 1);
        assert_eq!(reg.study_view(3).unwrap().trials.len(), 2);
    }

    #[test]
    fn cursor_roundtrip_and_rejection() {
        let c = Cursor { epoch: 12, index: 345 };
        assert_eq!(Cursor::decode(&c.encode()).unwrap(), c);
        for bad in ["", "v2.1.2", "v1.x.2", "v1.1", "v1.1.x", "garbage", "v1..", "v1.-1.0"] {
            assert!(Cursor::decode(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn trials_pages_concatenate_to_full_set() {
        let reg = registry();
        let mut s = study();
        reg.on_study_created(&s);
        for id in 0..25 {
            push_trial(&mut s, id);
        }
        reg.on_trials_inserted(&s, 0);
        let v = reg.study_view(3).unwrap();
        let mut seen = Vec::new();
        let mut cursor = Cursor { epoch: v.epoch, index: 0 };
        loop {
            let page = render_trials_page(&v, cursor, 7, None);
            let parsed = parse(&page).unwrap();
            for t in parsed.get("trials").as_arr().unwrap() {
                seen.push(t.get("id").as_u64().unwrap());
            }
            match parsed.get("next_cursor").as_str() {
                Some(c) => cursor = Cursor::decode(c).unwrap(),
                None => break,
            }
        }
        assert_eq!(seen, (0..25).collect::<Vec<u64>>());
    }

    #[test]
    fn state_filter_pages() {
        let reg = registry();
        let mut s = study();
        reg.on_study_created(&s);
        for id in 0..10 {
            push_trial(&mut s, id);
        }
        reg.on_trials_inserted(&s, 0);
        for slot in [1usize, 4, 7] {
            s.trials[slot].complete(slot as f64, 1.0).unwrap();
            s.note_scored(slot, 8);
            reg.on_trial_updated(&s, slot, Some(EventKind::Completed));
        }
        let v = reg.study_view(3).unwrap();
        let page = render_trials_page(
            &v,
            Cursor { epoch: v.epoch, index: 0 },
            2,
            Some(TrialState::Completed),
        );
        let parsed = parse(&page).unwrap();
        let ids: Vec<u64> = parsed
            .get("trials")
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.get("id").as_u64().unwrap())
            .collect();
        assert_eq!(ids, vec![1, 4]);
        let next = Cursor::decode(parsed.get("next_cursor").as_str().unwrap()).unwrap();
        let page2 = render_trials_page(&v, next, 2, Some(TrialState::Completed));
        let parsed2 = parse(&page2).unwrap();
        let ids2: Vec<u64> = parsed2
            .get("trials")
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.get("id").as_u64().unwrap())
            .collect();
        assert_eq!(ids2, vec![7]);
        assert!(parsed2.get("next_cursor").is_null());
    }

    #[test]
    fn studies_page_cursor_walk() {
        let reg = registry();
        for id in [2u64, 5, 9, 11] {
            let mut s = study();
            s.id = id;
            reg.on_study_created(&s);
        }
        let views = reg.study_views();
        assert_eq!(views.iter().map(|v| v.study_id).collect::<Vec<_>>(), vec![2, 5, 9, 11]);
        let page = render_studies_page(&views, None, 3);
        let parsed = parse(&page).unwrap();
        assert_eq!(parsed.get("studies").as_arr().unwrap().len(), 3);
        assert_eq!(parsed.get("next_cursor").as_str(), Some("9"));
        let page2 = render_studies_page(&views, Some(9), 3);
        let parsed2 = parse(&page2).unwrap();
        assert_eq!(parsed2.get("studies").as_arr().unwrap().len(), 1);
        assert!(parsed2.get("next_cursor").is_null());
    }

    #[test]
    fn rebuild_reconstructs_events_deterministically() {
        let reg = registry();
        let mut s = study();
        for id in 0..4 {
            push_trial(&mut s, id);
        }
        s.trials[2].complete(1.0, 5.0).unwrap();
        s.trials[0].prune(3.0).unwrap();
        s.trials[3].fail(5.0).unwrap();
        reg.rebuild_from(&s);
        let page = reg.events_after(3, 0, 100).unwrap();
        // Ordered by (finished_at, id): prune@3 → complete(id 2)@5 → fail(id 3)@5.
        let kinds: Vec<EventKind> = page.events.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![EventKind::Pruned, EventKind::Completed, EventKind::Failed]);
        assert_eq!(page.events[1].trial_id, 2);
        assert_eq!(page.events[2].trial_id, 3);
        // Deterministic: a second rebuild produces the same log.
        let reg2 = registry();
        reg2.rebuild_from(&s);
        let page2 = reg2.events_after(3, 0, 100).unwrap();
        for (a, b) in page.events.iter().zip(page2.events.iter()) {
            assert_eq!(a.json, b.json);
        }
    }

    #[test]
    fn trial_fragment_is_valid_json() {
        let mut t = crate::coordinator::trial::Trial::new(
            9,
            2,
            vec![("x".into(), crate::json::Value::Num(0.5))],
            1.0,
            Some("node-\"1\"".into()),
        );
        t.report(3, 0.75).unwrap();
        let lite = TrialLite::render(&t);
        let v = parse(&lite.json).unwrap();
        assert_eq!(v.get("id").as_u64(), Some(9));
        assert_eq!(v.get("state").as_str(), Some("running"));
        assert_eq!(v.get("node").as_str(), Some("node-\"1\""));
        assert_eq!(v.get("n_steps").as_u64(), Some(1));
        assert_eq!(v.get("last_step").as_u64(), Some(3));
        assert_eq!(v.get("last_value").as_f64(), Some(0.75));
        assert_eq!(v.get("params").get("x").as_f64(), Some(0.5));
    }
}

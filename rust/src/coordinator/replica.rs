//! Follower-side replication: the transport abstraction over the
//! primary's replication log, snapshot bootstrap for cold followers,
//! and the applier thread that feeds fetched batches into a live
//! [`Engine`].
//!
//! The catch-up protocol has three stages, all resumable:
//!
//! 1. **Bootstrap** — a follower whose data directory carries no state
//!    installs the primary's current snapshot bundle (manifest +
//!    segment files, see [`crate::store::read_snapshot_bundle`]). The
//!    manifest's per-shard cuts become the stream resume cursor.
//! 2. **Log tail** — the applier fetches acknowledged batches from the
//!    primary's in-memory replication buffer starting at the cursor,
//!    appending each record to the local WAL (with the primary's seq
//!    stamps preserved) and replaying it through the recovery
//!    machinery, so views, event logs, and fleet ledgers stay live.
//! 3. **Live stream** — once caught up, fetches long-poll: the
//!    primary parks the request until its next group commit.
//!
//! A follower restart re-enters at stage 2: the resume cursor is
//! recomputed from the last locally persisted record (or the manifest
//! cuts when the local log is empty), so no re-bootstrap is needed.
//! Only [`ReplFetch::TooOld`] — the primary evicted records the
//! follower still needs — forces a fresh bootstrap; the applier then
//! parks itself as *stalled* rather than apply a gapped stream.

use crate::coordinator::engine::Engine;
use crate::http::Client;
use crate::json::Value;
use crate::store::{self, Record, ReplFetch, ReplicationSource};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Largest batch a single fetch asks for. Bounds both the HTTP
/// response size and the per-batch apply latency on the follower.
const FETCH_MAX: usize = 4096;

/// A source of replication batches. [`HttpTransport`] speaks to a
/// remote primary over `/api/repl/*`; [`LocalTransport`] reads an
/// in-process [`ReplicationSource`] directly (tests and benches).
pub trait ReplTransport: Send {
    /// Fetch acknowledged records with `seq >= from`, at most `max`.
    /// `wait` bounds how long the call may block when the source is
    /// already caught up (long poll); `Duration::ZERO` returns
    /// immediately. Errors are transient (connection loss) — the
    /// caller retries with backoff.
    fn fetch(&mut self, from: u64, max: usize, wait: Duration) -> Result<ReplFetch, String>;

    /// The primary's current snapshot bundle
    /// (`{"manifest": ..., "files": [...]}`), for cold bootstrap.
    fn snapshot(&mut self) -> Result<Value, String>;
}

/// Parse a primary URL or address (`http://host:port`, `host:port`)
/// down to a socket address. Mirrors the worker client's handling of
/// the `primary` hint in follower 503 bodies.
pub fn parse_primary_addr(url: &str) -> Result<SocketAddr, String> {
    let rest = url
        .strip_prefix("http://")
        .or_else(|| url.strip_prefix("https://"))
        .unwrap_or(url);
    let host = rest.split('/').next().unwrap_or(rest);
    host.parse().map_err(|_| format!("unparseable primary address: {url}"))
}

/// Replication transport over HTTP: `GET /api/repl/log` (long poll)
/// and `GET /api/repl/snapshot` against the primary. Reconnects lazily
/// after any transport error.
pub struct HttpTransport {
    addr: SocketAddr,
    conn: Option<Client>,
}

impl HttpTransport {
    pub fn new(addr: SocketAddr) -> HttpTransport {
        HttpTransport { addr, conn: None }
    }

    pub fn from_url(url: &str) -> Result<HttpTransport, String> {
        Ok(HttpTransport::new(parse_primary_addr(url)?))
    }

    fn client(&mut self) -> Result<&mut Client, String> {
        if self.conn.is_none() {
            let c = Client::connect(self.addr)
                .map_err(|e| format!("connect {}: {e}", self.addr))?;
            self.conn = Some(c);
        }
        Ok(self.conn.as_mut().expect("just connected"))
    }
}

impl ReplTransport for HttpTransport {
    fn fetch(&mut self, from: u64, max: usize, wait: Duration) -> Result<ReplFetch, String> {
        let path =
            format!("/api/repl/log?from={from}&max={max}&timeout_ms={}", wait.as_millis());
        let resp = match self.client()?.get(&path) {
            Ok(r) => r,
            Err(e) => {
                self.conn = None;
                return Err(format!("repl log fetch: {e}"));
            }
        };
        match resp.status {
            200 => {
                let body = resp
                    .json_body()
                    .map_err(|e| format!("repl log body: {e}"))?;
                let mut records = Vec::new();
                for v in body.get("records").as_arr().unwrap_or(&[]) {
                    let Some(rec) = Record::from_value(v) else {
                        return Err("repl log: malformed record".into());
                    };
                    records.push(rec);
                }
                let next = body.get("next").as_u64().unwrap_or(from);
                let primary_next = body.get("primary_next").as_u64().unwrap_or(next);
                if records.is_empty() {
                    // Long-poll timeout with nothing new.
                    Ok(ReplFetch::UpToDate { next: primary_next })
                } else {
                    Ok(ReplFetch::Batches { records, next, primary_next })
                }
            }
            410 => {
                let oldest = resp
                    .json_body()
                    .ok()
                    .map(|b| b.get("oldest").as_u64().unwrap_or(0))
                    .unwrap_or(0);
                Ok(ReplFetch::TooOld { oldest })
            }
            s => {
                self.conn = None;
                Err(format!("repl log fetch: status {s}"))
            }
        }
    }

    fn snapshot(&mut self) -> Result<Value, String> {
        let resp = match self.client()?.get("/api/repl/snapshot") {
            Ok(r) => r,
            Err(e) => {
                self.conn = None;
                return Err(format!("repl snapshot fetch: {e}"));
            }
        };
        if resp.status != 200 {
            return Err(format!("repl snapshot: status {}", resp.status));
        }
        resp.json_body().map_err(|e| format!("repl snapshot body: {e}"))
    }
}

/// In-process transport reading a primary engine's
/// [`ReplicationSource`] directly — the seam tests, the property
/// harness, and `benches/replication.rs` use to drive a follower
/// without sockets. `dir` is the primary's data directory (for
/// snapshot bootstrap); `None` serves an empty bundle.
pub struct LocalTransport {
    source: Arc<ReplicationSource>,
    dir: Option<PathBuf>,
}

impl LocalTransport {
    pub fn new(source: Arc<ReplicationSource>, dir: Option<PathBuf>) -> LocalTransport {
        LocalTransport { source, dir }
    }
}

impl ReplTransport for LocalTransport {
    fn fetch(&mut self, from: u64, max: usize, wait: Duration) -> Result<ReplFetch, String> {
        let signal = self.source.signal();
        let seen = signal.generation();
        match self.source.fetch(from, max) {
            ReplFetch::UpToDate { .. } if !wait.is_zero() => {
                signal.wait_changed(seen, wait);
                Ok(self.source.fetch(from, max))
            }
            other => Ok(other),
        }
    }

    fn snapshot(&mut self) -> Result<Value, String> {
        match &self.dir {
            Some(d) => store::read_snapshot_bundle(d).map_err(|e| e.to_string()),
            None => {
                let mut o = Value::obj();
                o.set("manifest", Value::Null).set("files", Value::Arr(Vec::new()));
                Ok(Value::Obj(o))
            }
        }
    }
}

/// Install the primary's snapshot bundle into `dir` unless the
/// directory already carries state — a manifest from a previous
/// bootstrap, or locally persisted WAL records (then the recorded
/// stream cursor is the cheaper resume point, and overlaying a newer
/// manifest could mark those records covered out of order). Returns
/// whether a bundle was actually installed.
pub fn bootstrap(dir: &Path, transport: &mut dyn ReplTransport) -> Result<bool, String> {
    if dir.join("MANIFEST.json").exists() {
        return Ok(false);
    }
    let has_local_records = std::fs::metadata(dir.join("wal.log"))
        .map(|m| m.len() > 0)
        .unwrap_or(false);
    if has_local_records {
        return Ok(false);
    }
    let bundle = transport.snapshot()?;
    let installed = !bundle.get("manifest").is_null();
    store::install_snapshot_bundle(dir, &bundle).map_err(|e| e.to_string())?;
    Ok(installed)
}

/// The follower's apply loop: a thread that fetches batches from a
/// [`ReplTransport`] and feeds them through
/// [`Engine::apply_repl_batch`] until sealed (promotion), stalled
/// ([`ReplFetch::TooOld`] / apply failure), or dropped.
pub struct ReplicaApplier {
    stop: Arc<AtomicBool>,
    stalled: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ReplicaApplier {
    /// Spawn the apply loop. `poll` is the long-poll budget per fetch
    /// — it also bounds how long `seal`/drop wait for the thread to
    /// notice the stop flag.
    pub fn start(
        engine: Arc<Engine>,
        transport: Box<dyn ReplTransport>,
        poll: Duration,
    ) -> ReplicaApplier {
        let stop = Arc::new(AtomicBool::new(false));
        let stalled = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            let stalled = stalled.clone();
            std::thread::Builder::new()
                .name("repl-applier".into())
                .spawn(move || run(engine, transport, &stop, &stalled, poll))
                .expect("spawn repl applier thread")
        };
        ReplicaApplier { stop, stalled, handle: Some(handle) }
    }

    /// Whether the stream hit a condition only a re-bootstrap fixes.
    pub fn stalled(&self) -> bool {
        self.stalled.load(Ordering::Acquire)
    }

    /// Seal replication: signal the thread to stop, let it drain the
    /// residual tail the transport can still deliver (stopping fetches
    /// use a zero wait, so this is bounded by one in-flight long
    /// poll), and join. After `seal` returns the engine holds every
    /// record the transport would hand out — the precondition for
    /// [`Engine::promote`].
    pub fn seal(mut self) {
        self.join_inner();
    }

    fn join_inner(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ReplicaApplier {
    fn drop(&mut self) {
        self.join_inner();
    }
}

fn run(
    engine: Arc<Engine>,
    mut transport: Box<dyn ReplTransport>,
    stop: &AtomicBool,
    stalled: &AtomicBool,
    poll: Duration,
) {
    let mut backoff = Duration::from_millis(10);
    loop {
        let stopping = stop.load(Ordering::Acquire);
        let wait = if stopping { Duration::ZERO } else { poll };
        let from = engine.repl_next();
        match transport.fetch(from, FETCH_MAX, wait) {
            Ok(ReplFetch::Batches { records, next: _, primary_next }) => {
                backoff = Duration::from_millis(10);
                if let Err(e) = engine.apply_repl_batch(&records, primary_next) {
                    // Promoted underneath us, or local storage failed:
                    // either way this stream is over.
                    eprintln!("hopaas: replication apply stopped: {e}");
                    stalled.store(true, Ordering::Release);
                    return;
                }
            }
            Ok(ReplFetch::UpToDate { next }) => {
                backoff = Duration::from_millis(10);
                let _ = engine.apply_repl_batch(&[], next);
                if stopping {
                    return;
                }
                if poll.is_zero() {
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
            Ok(ReplFetch::TooOld { oldest }) => {
                eprintln!(
                    "hopaas: replication stalled: primary evicted up to seq {oldest}, \
                     follower needs {from}; re-bootstrap from a fresh snapshot"
                );
                stalled.store(true, Ordering::Release);
                return;
            }
            Err(e) => {
                if stopping {
                    return;
                }
                eprintln!("hopaas: replication fetch failed (retrying): {e}");
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(2));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_primary_addr_forms() {
        let want: SocketAddr = "127.0.0.1:8080".parse().unwrap();
        assert_eq!(parse_primary_addr("127.0.0.1:8080").unwrap(), want);
        assert_eq!(parse_primary_addr("http://127.0.0.1:8080").unwrap(), want);
        assert_eq!(parse_primary_addr("https://127.0.0.1:8080/api").unwrap(), want);
        assert!(parse_primary_addr("not an address").is_err());
    }

    #[test]
    fn bootstrap_skips_dirs_with_state() {
        let d = crate::testutil::TempDir::new("replica-bootstrap-skip");
        // Fabricate local WAL records: bootstrap must not overwrite.
        std::fs::write(d.path().join("wal.log"), b"x").unwrap();
        struct NoSnapshot;
        impl ReplTransport for NoSnapshot {
            fn fetch(&mut self, _: u64, _: usize, _: Duration) -> Result<ReplFetch, String> {
                Err("unused".into())
            }
            fn snapshot(&mut self) -> Result<Value, String> {
                panic!("bootstrap must not fetch a snapshot over local records");
            }
        }
        assert_eq!(bootstrap(d.path(), &mut NoSnapshot), Ok(false));
    }
}

//! Trial pruners.
//!
//! `should_prune` (paper §2) reports an intermediate `(step, value)` and
//! asks whether the trial is "sufficiently likely to result in an
//! improvement over the previous tests". Each pruner answers from the
//! intermediate histories of the study's other trials:
//!
//! | name          | rule |
//! |---------------|------|
//! | `none`        | never prune |
//! | `median`      | prune if the value is worse than the median of completed trials' values at the same step (Optuna's `MedianPruner`, with warmup) |
//! | `percentile`  | generalization: worse than the q-th percentile |
//! | `sha`         | asynchronous successive halving: at each rung `min_resource·η^k`, survive only if in the top 1/η of values seen at that rung |
//! | `hyperband`   | SHA with the bracket chosen per-trial (round-robin by trial id), covering multiple `min_resource` regimes |
//! | `threshold`   | prune on crossing an absolute bound (diverged loss) |
//! | `patient`     | prune if no improvement over the trial's own best for `patience` steps |
//!
//! All pruners are pure functions of `(trial, study history)` so the
//! decision is reproducible on WAL replay.

use super::space::Direction;
use super::study::AlgoConfig;
use super::trial::{Trial, TrialState};

/// Pruner interface. `history` is every other trial of the study
/// (any state); `trial` has already recorded the step being judged.
pub trait Pruner: Send {
    fn name(&self) -> &'static str;

    fn should_prune(
        &self,
        trial: &Trial,
        step: u64,
        value: f64,
        history: &[&Trial],
        direction: Direction,
    ) -> bool;
}

/// Instantiate from study configuration.
pub fn make_pruner(cfg: &AlgoConfig) -> Result<Box<dyn Pruner>, String> {
    match cfg.name.as_str() {
        "none" => Ok(Box::new(NonePruner)),
        "median" => Ok(Box::new(PercentilePruner {
            percentile: 50.0,
            warmup_steps: cfg.u64_opt("warmup_steps", 0),
            min_trials: cfg.u64_opt("min_trials", 4) as usize,
        })),
        "percentile" => Ok(Box::new(PercentilePruner {
            percentile: cfg.f64_opt("percentile", 25.0),
            warmup_steps: cfg.u64_opt("warmup_steps", 0),
            min_trials: cfg.u64_opt("min_trials", 4) as usize,
        })),
        "sha" | "successive_halving" => Ok(Box::new(ShaPruner {
            min_resource: cfg.u64_opt("min_resource", 1).max(1),
            reduction_factor: cfg.u64_opt("reduction_factor", 3).max(2),
            bracket_offset: 0,
        })),
        "hyperband" => Ok(Box::new(HyperbandPruner {
            min_resource: cfg.u64_opt("min_resource", 1).max(1),
            max_resource: cfg.u64_opt("max_resource", 81).max(2),
            reduction_factor: cfg.u64_opt("reduction_factor", 3).max(2),
        })),
        "threshold" => Ok(Box::new(ThresholdPruner {
            upper: cfg.options.get("upper").as_f64(),
            lower: cfg.options.get("lower").as_f64(),
        })),
        "patient" => Ok(Box::new(PatientPruner {
            patience: cfg.u64_opt("patience", 5),
            min_delta: cfg.f64_opt("min_delta", 0.0),
        })),
        other => Err(format!("unknown pruner '{other}'")),
    }
}

/// Never prunes.
pub struct NonePruner;

impl Pruner for NonePruner {
    fn name(&self) -> &'static str {
        "none"
    }

    fn should_prune(&self, _: &Trial, _: u64, _: f64, _: &[&Trial], _: Direction) -> bool {
        false
    }
}

/// Median/percentile pruner (Optuna `MedianPruner`/`PercentilePruner`).
pub struct PercentilePruner {
    /// Keep the trial if it is within the best `percentile`% at this step.
    pub percentile: f64,
    /// Never prune at steps below this.
    pub warmup_steps: u64,
    /// Need at least this many reference trials with a value at the step.
    pub min_trials: usize,
}

impl PercentilePruner {
    /// Reference values: other trials' intermediate value at `step`
    /// (completed or terminal trials only — running peers may be ahead or
    /// behind nondeterministically, matching Optuna which uses completed
    /// trials).
    fn reference_values(&self, step: u64, history: &[&Trial]) -> Vec<f64> {
        history
            .iter()
            .filter(|t| t.state == TrialState::Completed || t.state == TrialState::Pruned)
            .filter_map(|t| {
                // Value at the exact step, or the last report before it
                // (trials report on their own cadence).
                t.intermediate
                    .iter()
                    .take_while(|(s, _)| *s <= step)
                    .last()
                    .map(|(_, v)| *v)
            })
            .filter(|v| v.is_finite())
            .collect()
    }
}

impl Pruner for PercentilePruner {
    fn name(&self) -> &'static str {
        "percentile"
    }

    fn should_prune(
        &self,
        _trial: &Trial,
        step: u64,
        value: f64,
        history: &[&Trial],
        direction: Direction,
    ) -> bool {
        if step < self.warmup_steps {
            return false;
        }
        if !value.is_finite() {
            return true;
        }
        let mut refs = self.reference_values(step, history);
        if refs.len() < self.min_trials {
            return false;
        }
        refs.sort_by(f64::total_cmp);
        // Cutoff: the value must be at least as good as the q-th
        // percentile of references (q measured from the *best* side).
        let q = (self.percentile / 100.0).clamp(0.0, 1.0);
        let idx = ((refs.len() - 1) as f64 * q).round() as usize;
        let cutoff = match direction {
            Direction::Minimize => refs[idx],
            Direction::Maximize => refs[refs.len() - 1 - idx],
        };
        match direction {
            Direction::Minimize => value > cutoff,
            Direction::Maximize => value < cutoff,
        }
    }
}

/// Asynchronous successive halving (ASHA).
pub struct ShaPruner {
    pub min_resource: u64,
    pub reduction_factor: u64,
    /// Bracket shift (used by Hyperband).
    pub bracket_offset: u32,
}

impl ShaPruner {
    /// Rungs: min_resource · η^(offset + k).
    fn rung_steps(&self, up_to: u64) -> Vec<u64> {
        let mut rungs = Vec::new();
        let mut r = self
            .min_resource
            .saturating_mul(self.reduction_factor.pow(self.bracket_offset));
        while r <= up_to && rungs.len() < 32 {
            rungs.push(r);
            r = r.saturating_mul(self.reduction_factor);
        }
        rungs
    }

    /// Values competitors recorded at (or before, most recent) `rung`.
    fn rung_values(rung: u64, history: &[&Trial]) -> Vec<f64> {
        history
            .iter()
            .filter_map(|t| {
                t.intermediate
                    .iter()
                    .take_while(|(s, _)| *s <= rung)
                    .last()
                    .map(|(_, v)| *v)
            })
            .filter(|v| v.is_finite())
            .collect()
    }
}

impl Pruner for ShaPruner {
    fn name(&self) -> &'static str {
        "sha"
    }

    fn should_prune(
        &self,
        trial: &Trial,
        step: u64,
        value: f64,
        history: &[&Trial],
        direction: Direction,
    ) -> bool {
        if !value.is_finite() {
            return true;
        }
        // Judge only at rung boundaries (the latest rung ≤ step).
        let rungs = self.rung_steps(step);
        let Some(&rung) = rungs.last() else { return false };
        // The trial's own value at the rung: latest report ≤ rung.
        let own = trial
            .intermediate
            .iter()
            .take_while(|(s, _)| *s <= rung)
            .last()
            .map(|(_, v)| *v)
            .unwrap_or(value);
        let mut vals = Self::rung_values(rung, history);
        vals.push(own);
        let n = vals.len();
        // Need a meaningful cohort before halving.
        if n < self.reduction_factor as usize {
            return false;
        }
        vals.sort_by(f64::total_cmp);
        let keep = (n as u64 / self.reduction_factor).max(1) as usize;
        let survives = match direction {
            Direction::Minimize => own <= vals[keep - 1],
            Direction::Maximize => own >= vals[n - keep],
        };
        !survives
    }
}

/// Hyperband: a set of SHA brackets with different minimum resources;
/// each trial is assigned a bracket round-robin by id, so aggressive and
/// conservative halving regimes coexist (Li et al. 2018, as in Optuna).
pub struct HyperbandPruner {
    pub min_resource: u64,
    pub max_resource: u64,
    pub reduction_factor: u64,
}

impl HyperbandPruner {
    fn n_brackets(&self) -> u32 {
        let mut n = 1u32;
        let mut r = self.min_resource.max(1);
        while r * self.reduction_factor <= self.max_resource && n < 8 {
            r *= self.reduction_factor;
            n += 1;
        }
        n
    }
}

impl Pruner for HyperbandPruner {
    fn name(&self) -> &'static str {
        "hyperband"
    }

    fn should_prune(
        &self,
        trial: &Trial,
        step: u64,
        value: f64,
        history: &[&Trial],
        direction: Direction,
    ) -> bool {
        let bracket = (trial.id % self.n_brackets() as u64) as u32;
        let sha = ShaPruner {
            min_resource: self.min_resource,
            reduction_factor: self.reduction_factor,
            bracket_offset: bracket,
        };
        sha.should_prune(trial, step, value, history, direction)
    }
}

/// Absolute-bound pruner (catches diverged losses immediately).
pub struct ThresholdPruner {
    pub upper: Option<f64>,
    pub lower: Option<f64>,
}

impl Pruner for ThresholdPruner {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn should_prune(&self, _: &Trial, _: u64, value: f64, _: &[&Trial], _: Direction) -> bool {
        if !value.is_finite() {
            return true;
        }
        if let Some(u) = self.upper {
            if value > u {
                return true;
            }
        }
        if let Some(l) = self.lower {
            if value < l {
                return true;
            }
        }
        false
    }
}

/// Prune when the trial stops improving on itself (early stopping).
pub struct PatientPruner {
    pub patience: u64,
    pub min_delta: f64,
}

impl Pruner for PatientPruner {
    fn name(&self) -> &'static str {
        "patient"
    }

    fn should_prune(
        &self,
        trial: &Trial,
        _step: u64,
        value: f64,
        _history: &[&Trial],
        direction: Direction,
    ) -> bool {
        if !value.is_finite() {
            return true;
        }
        let series = &trial.intermediate;
        if series.len() <= self.patience as usize {
            return false;
        }
        // Best value before the patience window must beat everything in
        // the window (including the current value) by min_delta.
        let cut = series.len() - self.patience as usize;
        let best_before = series[..cut]
            .iter()
            .map(|(_, v)| *v)
            .fold(match direction {
                Direction::Minimize => f64::INFINITY,
                Direction::Maximize => f64::NEG_INFINITY,
            }, |a, b| match direction {
                Direction::Minimize => a.min(b),
                Direction::Maximize => a.max(b),
            });
        let best_in_window = series[cut..]
            .iter()
            .map(|(_, v)| *v)
            .chain(std::iter::once(value))
            .fold(match direction {
                Direction::Minimize => f64::INFINITY,
                Direction::Maximize => f64::NEG_INFINITY,
            }, |a, b| match direction {
                Direction::Minimize => a.min(b),
                Direction::Maximize => a.max(b),
            });
        match direction {
            Direction::Minimize => best_in_window > best_before - self.min_delta,
            Direction::Maximize => best_in_window < best_before + self.min_delta,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;

    fn trial_with(id: u64, series: &[(u64, f64)], state: TrialState) -> Trial {
        let mut t = Trial::new(id, id, vec![("x".into(), Value::Num(0.5))], 0.0, None);
        for &(s, v) in series {
            t.report(s, v).unwrap();
        }
        match state {
            TrialState::Completed => t.complete(series.last().map(|x| x.1).unwrap_or(0.0), 1.0).unwrap(),
            TrialState::Pruned => t.prune(1.0).unwrap(),
            TrialState::Failed => t.fail(1.0).unwrap(),
            TrialState::Running => {}
        }
        t
    }

    #[test]
    fn median_prunes_bad_trial() {
        // Four completed trials with loss 1.0 at step 5; candidate at 10.0.
        let hist: Vec<Trial> = (0..4)
            .map(|i| trial_with(i, &[(5, 1.0 + i as f64 * 0.01)], TrialState::Completed))
            .collect();
        let refs: Vec<&Trial> = hist.iter().collect();
        let p = PercentilePruner { percentile: 50.0, warmup_steps: 0, min_trials: 4 };
        let cand = trial_with(99, &[(5, 10.0)], TrialState::Running);
        assert!(p.should_prune(&cand, 5, 10.0, &refs, Direction::Minimize));
        let good = trial_with(98, &[(5, 0.5)], TrialState::Running);
        assert!(!p.should_prune(&good, 5, 0.5, &refs, Direction::Minimize));
    }

    #[test]
    fn median_respects_warmup_and_min_trials() {
        let hist: Vec<Trial> =
            (0..2).map(|i| trial_with(i, &[(5, 1.0)], TrialState::Completed)).collect();
        let refs: Vec<&Trial> = hist.iter().collect();
        let p = PercentilePruner { percentile: 50.0, warmup_steps: 10, min_trials: 4 };
        let cand = trial_with(99, &[(5, 100.0)], TrialState::Running);
        // Below warmup.
        assert!(!p.should_prune(&cand, 5, 100.0, &refs, Direction::Minimize));
        // Past warmup but too few reference trials.
        let p2 = PercentilePruner { percentile: 50.0, warmup_steps: 0, min_trials: 4 };
        assert!(!p2.should_prune(&cand, 5, 100.0, &refs, Direction::Minimize));
    }

    #[test]
    fn median_direction_maximize() {
        let hist: Vec<Trial> = (0..4)
            .map(|i| trial_with(i, &[(3, 0.8 + 0.01 * i as f64)], TrialState::Completed))
            .collect();
        let refs: Vec<&Trial> = hist.iter().collect();
        let p = PercentilePruner { percentile: 50.0, warmup_steps: 0, min_trials: 4 };
        let bad = trial_with(99, &[(3, 0.1)], TrialState::Running);
        assert!(p.should_prune(&bad, 3, 0.1, &refs, Direction::Maximize));
        let good = trial_with(98, &[(3, 0.95)], TrialState::Running);
        assert!(!p.should_prune(&good, 3, 0.95, &refs, Direction::Maximize));
    }

    #[test]
    fn nonfinite_always_pruned() {
        let p = PercentilePruner { percentile: 50.0, warmup_steps: 0, min_trials: 4 };
        let cand = trial_with(1, &[], TrialState::Running);
        assert!(p.should_prune(&cand, 1, f64::NAN, &[], Direction::Minimize));
        let t = ThresholdPruner { upper: None, lower: None };
        assert!(t.should_prune(&cand, 1, f64::INFINITY, &[], Direction::Minimize));
    }

    #[test]
    fn sha_halves_at_rungs() {
        // 9 competitors at rung 1 with values 1..9; η=3 keeps top 3.
        let hist: Vec<Trial> = (0..9)
            .map(|i| trial_with(i, &[(1, (i + 1) as f64)], TrialState::Running))
            .collect();
        let refs: Vec<&Trial> = hist.iter().collect();
        let sha = ShaPruner { min_resource: 1, reduction_factor: 3, bracket_offset: 0 };
        let good = trial_with(90, &[(1, 0.5)], TrialState::Running);
        assert!(!sha.should_prune(&good, 1, 0.5, &refs, Direction::Minimize));
        let bad = trial_with(91, &[(1, 8.5)], TrialState::Running);
        assert!(sha.should_prune(&bad, 1, 8.5, &refs, Direction::Minimize));
    }

    #[test]
    fn sha_no_decision_off_rung_with_min_resource() {
        let sha = ShaPruner { min_resource: 4, reduction_factor: 2, bracket_offset: 0 };
        let cand = trial_with(1, &[(2, 100.0)], TrialState::Running);
        // Step 2 < min_resource 4: no rung reached yet.
        assert!(!sha.should_prune(&cand, 2, 100.0, &[], Direction::Minimize));
    }

    #[test]
    fn sha_small_cohort_not_pruned() {
        let sha = ShaPruner { min_resource: 1, reduction_factor: 3, bracket_offset: 0 };
        let hist = vec![trial_with(0, &[(1, 0.1)], TrialState::Running)];
        let refs: Vec<&Trial> = hist.iter().collect();
        let cand = trial_with(1, &[(1, 5.0)], TrialState::Running);
        // Cohort of 2 < η=3: survive.
        assert!(!sha.should_prune(&cand, 1, 5.0, &refs, Direction::Minimize));
    }

    #[test]
    fn hyperband_brackets_differ_by_trial_id() {
        let hb = HyperbandPruner { min_resource: 1, max_resource: 81, reduction_factor: 3 };
        assert!(hb.n_brackets() >= 4);
        // A trial in bracket 0 is judged at step 1; a trial in a later
        // bracket is not (its first rung is higher).
        let hist: Vec<Trial> = (0..9)
            .map(|i| trial_with(100 + i, &[(1, (i + 1) as f64)], TrialState::Running))
            .collect();
        let refs: Vec<&Trial> = hist.iter().collect();
        let b0 = trial_with(hb.n_brackets() as u64 * 10, &[(1, 50.0)], TrialState::Running); // id % n == 0
        assert!(hb.should_prune(&b0, 1, 50.0, &refs, Direction::Minimize));
        let b1 = trial_with(hb.n_brackets() as u64 * 10 + 1, &[(1, 50.0)], TrialState::Running);
        assert!(!hb.should_prune(&b1, 1, 50.0, &refs, Direction::Minimize), "bracket 1 first rung is 3");
    }

    #[test]
    fn threshold_bounds() {
        let t = ThresholdPruner { upper: Some(10.0), lower: Some(-1.0) };
        let cand = trial_with(1, &[], TrialState::Running);
        assert!(t.should_prune(&cand, 1, 11.0, &[], Direction::Minimize));
        assert!(t.should_prune(&cand, 1, -2.0, &[], Direction::Minimize));
        assert!(!t.should_prune(&cand, 1, 5.0, &[], Direction::Minimize));
    }

    #[test]
    fn patient_prunes_stagnation() {
        let p = PatientPruner { patience: 3, min_delta: 0.0 };
        // Improving: 5,4,3,2 → no prune.
        let improving = trial_with(1, &[(1, 5.0), (2, 4.0), (3, 3.0), (4, 2.0)], TrialState::Running);
        assert!(!p.should_prune(&improving, 5, 1.5, &[], Direction::Minimize));
        // Stagnant after step 1: 1, 2, 2, 2 → prune.
        let stagnant = trial_with(2, &[(1, 1.0), (2, 2.0), (3, 2.0), (4, 2.0)], TrialState::Running);
        assert!(p.should_prune(&stagnant, 5, 2.0, &[], Direction::Minimize));
    }

    #[test]
    fn factory_dispatch() {
        for name in ["none", "median", "percentile", "sha", "hyperband", "threshold", "patient"] {
            assert!(make_pruner(&AlgoConfig::new(name)).is_ok(), "{name}");
        }
        assert!(make_pruner(&AlgoConfig::new("wat")).is_err());
    }
}

//! The coordination engine: the transactional core behind the REST APIs.
//!
//! The engine owns the study registry and applies the three HOPAAS
//! mutations (`ask`, `tell`, `should_prune`) under one lock, persisting
//! each accepted mutation to the WAL *before* acknowledging it — so a
//! crash never loses a told trial (paper's campaigns run for days on
//! opportunistic resources; E7 tests this).
//!
//! Determinism: sampler draws are seeded from
//! `mix(study_key_hash, trial_number)`, so recovery replay or a second
//! server instance reading the same WAL produces the same suggestion
//! stream — the property PostgreSQL gives the paper's "scalable set of
//! Uvicorn instances".

use super::samplers::{make_sampler, Obs};
use super::space::assignment_to_json;
use super::study::{parse_ask_body, Study, StudyDef};
use super::trial::{Trial, TrialState};
use super::{metrics::Metrics, pruners::make_pruner};
use crate::json::Value;
use crate::rng::{mix, Rng};
use crate::store::{Record, Storage};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// API-level error → HTTP status mapping happens in the service layer.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ApiError {
    #[error("{0}")]
    BadRequest(String),
    #[error("{0}")]
    NotFound(String),
    #[error("{0}")]
    Conflict(String),
    #[error("storage failure: {0}")]
    Storage(String),
}

/// Engine tuning.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Base seed for the deterministic sampler streams.
    pub seed: u64,
    /// Compact the WAL into a snapshot after this many records.
    pub compact_after: u64,
    /// Mark a running trial failed if silent for this many seconds
    /// (opportunistic nodes vanish without a goodbye). `None` disables.
    pub reap_after: Option<f64>,
    /// §Perf: clone at most this many (most recent) scored observations
    /// into the per-ask sampler snapshot. Every model-based sampler
    /// windows its history anyway (TPE 1024, GP 256, CMA-ES λ·gens), so
    /// cloning the full multi-thousand-trial history per ask is pure
    /// waste.
    pub history_snapshot: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            seed: 0x4f50_5441_4153,
            compact_after: 50_000,
            reap_after: Some(3600.0),
            history_snapshot: 2048,
        }
    }
}

/// Response of a successful `ask`.
#[derive(Clone, Debug)]
pub struct AskReply {
    pub trial_id: u64,
    pub trial_number: u64,
    pub study_id: u64,
    pub study_key: String,
    pub params: Value,
}

struct Inner {
    studies: Vec<Study>,
    by_key: HashMap<String, usize>,
    /// trial id → (study index, trial index)
    trial_index: HashMap<u64, (usize, usize)>,
    next_trial_id: u64,
    storage: Option<Storage>,
    wal_records: u64,
    /// trial id → last report wall time (not persisted; reaping is a
    /// liveness heuristic, not state).
    last_seen: HashMap<u64, f64>,
}

/// The coordination engine. Thread-safe; the HTTP layer shares it.
pub struct Engine {
    inner: Mutex<Inner>,
    config: EngineConfig,
    start: Instant,
    pub metrics: Arc<Metrics>,
    /// Total asks served (for quick health output).
    asks: AtomicU64,
}

impl Engine {
    /// In-memory engine (tests, benches).
    pub fn in_memory(config: EngineConfig) -> Engine {
        Engine {
            inner: Mutex::new(Inner {
                studies: Vec::new(),
                by_key: HashMap::new(),
                trial_index: HashMap::new(),
                next_trial_id: 1,
                storage: None,
                wal_records: 0,
                last_seen: HashMap::new(),
            }),
            config,
            start: Instant::now(),
            metrics: Arc::new(Metrics::default()),
            asks: AtomicU64::new(0),
        }
    }

    /// Durable engine: replays snapshot + WAL from `dir`.
    pub fn open(dir: impl AsRef<std::path::Path>, config: EngineConfig) -> Result<Engine, ApiError> {
        let mut storage =
            Storage::open(dir).map_err(|e| ApiError::Storage(e.to_string()))?;
        let (snapshot, events) =
            storage.load().map_err(|e| ApiError::Storage(e.to_string()))?;
        let engine = Engine::in_memory(config);
        {
            let mut inner = engine.inner.lock().unwrap();
            if let Some(snap) = snapshot {
                Self::apply_snapshot(&mut inner, &snap)?;
            }
            for ev in &events {
                Self::apply_event(&mut inner, ev);
            }
            inner.wal_records = events.len() as u64;
            inner.storage = Some(storage);
        }
        Ok(engine)
    }

    /// Seconds since engine start — the time base used across the
    /// coordinator.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    // ------------------------------------------------------------------
    // Table 1 APIs
    // ------------------------------------------------------------------

    /// `ask`: create a trial in the study defined by `body`; returns the
    /// suggested hyperparameters.
    ///
    /// Locking (§Perf): the surrogate refit (TPE KDE / GP Cholesky) is
    /// the expensive part of an ask, so it runs on a *snapshot* of the
    /// study history taken under the lock, with the lock released. A
    /// concurrent ask may therefore suggest from history that is one or
    /// two tells stale — the same semantics Optuna has in distributed
    /// mode, and irrelevant statistically (the history grows by whole
    /// trials, the surrogate by one observation). The lock is re-taken
    /// only to insert the trial record.
    pub fn ask(&self, body: &Value) -> Result<AskReply, ApiError> {
        let (def, node) = parse_ask_body(body).map_err(ApiError::BadRequest)?;
        let now = self.now();
        let key = def.key();
        if def.is_mo() {
            return self.ask_mo(def, node, now, key);
        }
        let sampler = make_sampler(&def.sampler).map_err(ApiError::BadRequest)?;

        // --- critical section 1: find/create study, snapshot history ---
        let (study_idx, trial_number, scored, space, direction) = {
            let mut inner = self.inner.lock().unwrap();
            let inner = &mut *inner;
            let study_idx = Self::find_or_create_study(inner, &def, now, &key, &self.metrics)?;
            let study = &inner.studies[study_idx];
            let trial_number = study.trials.len() as u64;
            let all = study.scored();
            let skip = all.len().saturating_sub(self.config.history_snapshot.max(1));
            let scored: Vec<Obs> = all
                .into_iter()
                .skip(skip)
                .map(|(t, v)| Obs { params: t.params.clone(), value: v })
                .collect();
            (
                study_idx,
                trial_number,
                scored,
                study.def.space.clone(),
                study.def.direction,
            )
        };

        // --- suggest OUTSIDE the lock (deterministic per study+number) ---
        let key_hash = {
            let mut h: u64 = 0xcbf29ce484222325;
            for b in key.as_bytes() {
                h = (h ^ *b as u64).wrapping_mul(0x100000001b3);
            }
            h
        };
        let mut rng = Rng::new(mix(mix(self.config.seed, key_hash), trial_number));
        let params = sampler.suggest(&space, &scored, direction, trial_number, &mut rng);

        // --- critical section 2: insert the trial ---
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        // trial_number may have advanced while we sampled; re-read it so
        // `number` stays the creation-order index.
        let trial_number = inner.studies[study_idx].trials.len() as u64;
        let trial_id = inner.next_trial_id;
        inner.next_trial_id += 1;
        let trial = Trial::new(trial_id, trial_number, params.clone(), now, node);
        let ev = {
            let mut o = Value::obj();
            o.set("study_id", inner.studies[study_idx].id)
                .set("trial", trial.to_json());
            Value::Obj(o)
        };
        let trial_idx = inner.studies[study_idx].trials.len();
        inner.studies[study_idx].trials.push(trial);
        inner.trial_index.insert(trial_id, (study_idx, trial_idx));
        inner.last_seen.insert(trial_id, now);
        Self::persist(inner, Record::new("trial_new", ev))?;

        self.metrics.trials_created.inc();
        self.metrics.ask_total.inc();
        self.asks.fetch_add(1, Ordering::Relaxed);
        self.maybe_compact(inner);

        let study = &inner.studies[study_idx];
        Ok(AskReply {
            trial_id,
            trial_number,
            study_id: study.id,
            study_key: study.key.clone(),
            params: assignment_to_json(&study.trials[trial_idx].params),
        })
    }

    /// `ask` for a multi-objective study (paper §5 future work): same
    /// protocol, but the suggestion comes from NSGA-II over the study's
    /// objective *vectors*. Default sampler name "tpe" (the protocol
    /// default) is interpreted as "nsga2" for MO studies; random/grid/
    /// qmc work as-is; gp/cmaes are single-objective only.
    fn ask_mo(
        &self,
        def: super::study::StudyDef,
        node: Option<String>,
        now: f64,
        key: String,
    ) -> Result<AskReply, ApiError> {
        use super::samplers::nsga2::{MoObs, Nsga2Sampler};
        let directions = def.directions.clone().expect("mo study");
        enum MoWhich {
            Nsga2(Nsga2Sampler),
            Plain(Box<dyn super::samplers::Sampler>),
        }
        let which = match def.sampler.name.as_str() {
            "nsga2" | "tpe" => MoWhich::Nsga2(Nsga2Sampler::from_config(&def.sampler)),
            "random" | "grid" | "qmc" | "sobol" => {
                MoWhich::Plain(make_sampler(&def.sampler).map_err(ApiError::BadRequest)?)
            }
            other => {
                return Err(ApiError::BadRequest(format!(
                    "sampler '{other}' does not support multi-objective studies"
                )))
            }
        };

        // --- critical section 1: find/create study + snapshot ---
        let (study_idx, trial_number, mo_obs, space) = {
            let mut inner = self.inner.lock().unwrap();
            let inner = &mut *inner;
            let study_idx = Self::find_or_create_study(inner, &def, now, &key, &self.metrics)?;
            let study = &inner.studies[study_idx];
            let trial_number = study.trials.len() as u64;
            let skip = study
                .mo_scored()
                .len()
                .saturating_sub(self.config.history_snapshot.max(1));
            let mo_obs: Vec<MoObs> = study
                .mo_scored()
                .into_iter()
                .skip(skip)
                .map(|(t, v)| MoObs { params: t.params.clone(), values: v.clone() })
                .collect();
            (study_idx, trial_number, mo_obs, study.def.space.clone())
        };

        // --- suggest outside the lock ---
        let key_hash = {
            let mut h: u64 = 0xcbf29ce484222325;
            for b in key.as_bytes() {
                h = (h ^ *b as u64).wrapping_mul(0x100000001b3);
            }
            h
        };
        let mut rng = Rng::new(mix(mix(self.config.seed, key_hash), trial_number));
        let params = match which {
            MoWhich::Nsga2(s) => s.suggest_mo(&space, &mo_obs, &directions, &mut rng),
            MoWhich::Plain(s) => {
                s.suggest(&space, &[], super::space::Direction::Minimize, trial_number, &mut rng)
            }
        };

        // --- critical section 2: insert the trial ---
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let trial_number = inner.studies[study_idx].trials.len() as u64;
        let trial_id = inner.next_trial_id;
        inner.next_trial_id += 1;
        let trial = Trial::new(trial_id, trial_number, params, now, node);
        let ev = {
            let mut o = Value::obj();
            o.set("study_id", inner.studies[study_idx].id)
                .set("trial", trial.to_json());
            Value::Obj(o)
        };
        let trial_idx = inner.studies[study_idx].trials.len();
        inner.studies[study_idx].trials.push(trial);
        inner.trial_index.insert(trial_id, (study_idx, trial_idx));
        inner.last_seen.insert(trial_id, now);
        Self::persist(inner, Record::new("trial_new", ev))?;
        self.metrics.trials_created.inc();
        self.metrics.ask_total.inc();
        self.asks.fetch_add(1, Ordering::Relaxed);
        self.maybe_compact(inner);
        let study = &inner.studies[study_idx];
        Ok(AskReply {
            trial_id,
            trial_number,
            study_id: study.id,
            study_key: study.key.clone(),
            params: assignment_to_json(&study.trials[trial_idx].params),
        })
    }

    /// `tell` with an objective vector (multi-objective studies).
    /// Returns `(study_id, on_pareto_front)`.
    pub fn tell_values(&self, trial_id: u64, values: Vec<f64>) -> Result<(u64, bool), ApiError> {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let (si, ti) = *inner
            .trial_index
            .get(&trial_id)
            .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;
        let Some(directions) = inner.studies[si].def.directions.clone() else {
            return Err(ApiError::BadRequest(
                "'values' array sent to a single-objective study".into(),
            ));
        };
        if values.len() != directions.len() {
            return Err(ApiError::BadRequest(format!(
                "expected {} objective values, got {}",
                directions.len(),
                values.len()
            )));
        }
        inner.studies[si].trials[ti]
            .complete_mo(values.clone(), now)
            .map_err(|e| ApiError::Conflict(e.to_string()))?;
        let ev = {
            let mut o = Value::obj();
            o.set("trial_id", trial_id)
                .set(
                    "values",
                    Value::Arr(values.iter().map(|&v| Value::Num(v)).collect()),
                )
                .set("at", now);
            Value::Obj(o)
        };
        Self::persist(inner, Record::new("trial_tell_mo", ev))?;
        inner.last_seen.remove(&trial_id);
        self.metrics.tell_total.inc();
        self.metrics.trials_completed.inc();
        self.maybe_compact(inner);
        let on_front = inner.studies[si]
            .pareto()
            .iter()
            .any(|t| t.id == trial_id);
        Ok((inner.studies[si].id, on_front))
    }

    /// Pareto front of a multi-objective study (dashboard/client API).
    pub fn pareto_json(&self, study_id: u64) -> Option<Value> {
        let inner = self.inner.lock().unwrap();
        let study = inner.studies.iter().find(|s| s.id == study_id)?;
        Some(Value::Arr(
            study.pareto().into_iter().map(|t| t.to_json()).collect(),
        ))
    }

    /// `tell`: finalize a trial with its objective value.
    /// Returns `(study_id, is_best)`.
    pub fn tell(&self, trial_id: u64, value: f64) -> Result<(u64, bool), ApiError> {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let (si, ti) = *inner
            .trial_index
            .get(&trial_id)
            .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;
        let direction = inner.studies[si].def.direction;
        let prev_best = inner.studies[si].best().and_then(|t| t.value);
        inner.studies[si].trials[ti]
            .complete(value, now)
            .map_err(|e| ApiError::Conflict(e.to_string()))?;
        let ev = {
            let mut o = Value::obj();
            o.set("trial_id", trial_id).set("value", value).set("at", now);
            Value::Obj(o)
        };
        Self::persist(inner, Record::new("trial_tell", ev))?;
        inner.last_seen.remove(&trial_id);
        self.metrics.tell_total.inc();
        self.metrics.trials_completed.inc();
        self.maybe_compact(inner);
        let is_best = match prev_best {
            None => true,
            Some(b) => direction.better(value, b),
        };
        Ok((inner.studies[si].id, is_best))
    }

    /// `should_prune`: record an intermediate value; returns whether the
    /// client should abort the trial. A `true` response transitions the
    /// trial to Pruned server-side (the client contract is to stop).
    pub fn should_prune(&self, trial_id: u64, step: u64, value: f64) -> Result<bool, ApiError> {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let (si, ti) = *inner
            .trial_index
            .get(&trial_id)
            .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;

        inner.studies[si].trials[ti]
            .report(step, value)
            .map_err(|e| ApiError::Conflict(e.to_string()))?;
        inner.last_seen.insert(trial_id, now);
        let ev = {
            let mut o = Value::obj();
            o.set("trial_id", trial_id).set("step", step).set("value", value);
            Value::Obj(o)
        };
        Self::persist(inner, Record::new("trial_report", ev))?;
        self.metrics.should_prune_total.inc();

        let study = &inner.studies[si];
        let prune = match &study.def.pruner {
            None => false,
            Some(cfg) => {
                let pruner = make_pruner(cfg).map_err(ApiError::BadRequest)?;
                let trial = &study.trials[ti];
                let history: Vec<&Trial> = study
                    .trials
                    .iter()
                    .filter(|t| t.id != trial_id)
                    .collect();
                pruner.should_prune(trial, step, value, &history, study.def.direction)
            }
        };
        if prune {
            inner.studies[si].trials[ti]
                .prune(now)
                .map_err(|e| ApiError::Conflict(e.to_string()))?;
            let ev = {
                let mut o = Value::obj();
                o.set("trial_id", trial_id).set("at", now);
                Value::Obj(o)
            };
            Self::persist(inner, Record::new("trial_prune", ev))?;
            inner.last_seen.remove(&trial_id);
            self.metrics.prune_decisions.inc();
            self.metrics.trials_pruned.inc();
        }
        self.maybe_compact(inner);
        Ok(prune)
    }

    /// Client-reported failure (e.g. OOM) — frees the trial slot.
    pub fn fail(&self, trial_id: u64) -> Result<(), ApiError> {
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let (si, ti) = *inner
            .trial_index
            .get(&trial_id)
            .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;
        inner.studies[si].trials[ti]
            .fail(now)
            .map_err(|e| ApiError::Conflict(e.to_string()))?;
        let ev = {
            let mut o = Value::obj();
            o.set("trial_id", trial_id).set("at", now);
            Value::Obj(o)
        };
        Self::persist(inner, Record::new("trial_fail", ev))?;
        inner.last_seen.remove(&trial_id);
        self.metrics.trials_failed.inc();
        Ok(())
    }

    /// Reap running trials whose node has been silent past the deadline
    /// (called periodically by the server loop).
    pub fn reap_stale(&self) -> usize {
        let Some(deadline) = self.config.reap_after else { return 0 };
        let now = self.now();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let stale: Vec<u64> = inner
            .last_seen
            .iter()
            .filter(|(_, &t)| now - t > deadline)
            .map(|(&id, _)| id)
            .collect();
        let mut reaped = 0;
        for id in stale {
            if let Some(&(si, ti)) = inner.trial_index.get(&id) {
                if inner.studies[si].trials[ti].fail(now).is_ok() {
                    let ev = {
                        let mut o = Value::obj();
                        o.set("trial_id", id).set("at", now);
                        Value::Obj(o)
                    };
                    let _ = Self::persist(inner, Record::new("trial_fail", ev));
                    self.metrics.trials_failed.inc();
                    reaped += 1;
                }
            }
            inner.last_seen.remove(&id);
        }
        reaped
    }

    // ------------------------------------------------------------------
    // Read APIs (dashboard / web data)
    // ------------------------------------------------------------------

    /// Summaries of all studies.
    pub fn studies_json(&self) -> Value {
        let inner = self.inner.lock().unwrap();
        Value::Arr(inner.studies.iter().map(|s| s.summary_json()).collect())
    }

    /// One study's summary.
    pub fn study_json(&self, study_id: u64) -> Option<Value> {
        let inner = self.inner.lock().unwrap();
        inner
            .studies
            .iter()
            .find(|s| s.id == study_id)
            .map(|s| s.summary_json())
    }

    /// A study's full trial list.
    pub fn trials_json(&self, study_id: u64) -> Option<Value> {
        let inner = self.inner.lock().unwrap();
        inner
            .studies
            .iter()
            .find(|s| s.id == study_id)
            .map(|s| Value::Arr(s.trials.iter().map(|t| t.to_json()).collect()))
    }

    /// Loss-curve series for the dashboard plots (paper: Chartist plots
    /// of "the evolution of the loss reported by different studies and
    /// trials").
    pub fn series_json(&self, study_id: u64) -> Option<Value> {
        let inner = self.inner.lock().unwrap();
        let study = inner.studies.iter().find(|s| s.id == study_id)?;
        let mut arr = Vec::new();
        for t in &study.trials {
            let mut o = Value::obj();
            o.set("trial", t.id)
                .set("state", t.state.as_str())
                .set(
                    "points",
                    Value::Arr(
                        t.intermediate
                            .iter()
                            .map(|(s, v)| Value::Arr(vec![Value::Num(*s as f64), Value::Num(*v)]))
                            .collect(),
                    ),
                )
                .set("final", t.value);
            arr.push(Value::Obj(o));
        }
        Some(Value::Arr(arr))
    }

    /// Best-so-far curve of a study: (trial number, best value after it).
    pub fn best_curve(&self, study_id: u64) -> Option<Vec<(u64, f64)>> {
        let inner = self.inner.lock().unwrap();
        let study = inner.studies.iter().find(|s| s.id == study_id)?;
        let mut best: Option<f64> = None;
        let mut curve = Vec::new();
        for t in &study.trials {
            if let (TrialState::Completed, Some(v)) = (t.state, t.value) {
                best = Some(match best {
                    None => v,
                    Some(b) if study.def.direction.better(v, b) => v,
                    Some(b) => b,
                });
                curve.push((t.number, best.unwrap()));
            }
        }
        Some(curve)
    }

    /// Number of studies.
    pub fn n_studies(&self) -> usize {
        self.inner.lock().unwrap().studies.len()
    }

    /// Look up a study id by definition key.
    pub fn study_id_by_key(&self, key: &str) -> Option<u64> {
        let inner = self.inner.lock().unwrap();
        inner.by_key.get(key).map(|&i| inner.studies[i].id)
    }

    /// Force a snapshot + WAL truncation.
    pub fn compact(&self) -> Result<(), ApiError> {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        Self::compact_inner(inner)
    }

    // ------------------------------------------------------------------
    // Persistence plumbing
    // ------------------------------------------------------------------

    /// Locate the study for `key`, creating (and persisting) it if new.
    fn find_or_create_study(
        inner: &mut Inner,
        def: &StudyDef,
        now: f64,
        key: &str,
        metrics: &Metrics,
    ) -> Result<usize, ApiError> {
        match inner.by_key.get(key) {
            Some(&i) => Ok(i),
            None => {
                let id = inner.studies.len() as u64 + 1;
                let ev_payload = {
                    let mut o = Value::obj();
                    o.set("id", id).set("def", def.canonical_json());
                    Value::Obj(o)
                };
                let study = Study::new(id, def.clone(), now);
                inner.studies.push(study);
                let idx = inner.studies.len() - 1;
                inner.by_key.insert(key.to_string(), idx);
                metrics.studies_created.inc();
                Self::persist(inner, Record::new("study_new", ev_payload))?;
                Ok(idx)
            }
        }
    }

    fn persist(inner: &mut Inner, record: Record) -> Result<(), ApiError> {
        if let Some(storage) = inner.storage.as_mut() {
            storage
                .append(&record)
                .map_err(|e| ApiError::Storage(e.to_string()))?;
            inner.wal_records += 1;
        }
        Ok(())
    }

    fn maybe_compact(&self, inner: &mut Inner) {
        if inner.storage.is_some() && inner.wal_records >= self.config.compact_after {
            let _ = Self::compact_inner(inner);
        }
    }

    fn compact_inner(inner: &mut Inner) -> Result<(), ApiError> {
        if inner.storage.is_none() {
            return Ok(());
        }
        let snap = Self::snapshot_value(inner);
        let storage = inner.storage.as_mut().unwrap();
        storage
            .compact(&snap)
            .map_err(|e| ApiError::Storage(e.to_string()))?;
        inner.wal_records = 0;
        Ok(())
    }

    fn snapshot_value(inner: &Inner) -> Value {
        let mut studies = Vec::new();
        for s in &inner.studies {
            let mut o = Value::obj();
            o.set("id", s.id)
                .set("def", s.def.canonical_json())
                .set("created_at", s.created_at)
                .set(
                    "trials",
                    Value::Arr(s.trials.iter().map(|t| t.to_json()).collect()),
                );
            studies.push(Value::Obj(o));
        }
        let mut o = Value::obj();
        o.set("studies", Value::Arr(studies))
            .set("next_trial_id", inner.next_trial_id);
        Value::Obj(o)
    }

    fn apply_snapshot(inner: &mut Inner, snap: &Value) -> Result<(), ApiError> {
        for sv in snap.get("studies").as_arr().unwrap_or(&[]) {
            let (def, _) = parse_ask_body(sv.get("def"))
                .map_err(|e| ApiError::Storage(format!("snapshot study def: {e}")))?;
            let def = StudyDef {
                // canonical_json stores name/sampler/pruner explicitly.
                name: sv.get("def").get("name").as_str().unwrap_or("default").into(),
                ..def
            };
            let id = sv.get("id").as_u64().unwrap_or(0);
            let mut study = Study::new(id, def, sv.get("created_at").as_f64().unwrap_or(0.0));
            for tv in sv.get("trials").as_arr().unwrap_or(&[]) {
                if let Some(t) = Trial::from_json(tv) {
                    study.trials.push(t);
                }
            }
            let idx = inner.studies.len();
            inner.by_key.insert(study.key.clone(), idx);
            for (ti, t) in study.trials.iter().enumerate() {
                inner.trial_index.insert(t.id, (idx, ti));
            }
            inner.studies.push(study);
        }
        inner.next_trial_id = snap.get("next_trial_id").as_u64().unwrap_or(1);
        Ok(())
    }

    fn apply_event(inner: &mut Inner, record: &Record) {
        match record.tag.as_str() {
            "study_new" => {
                let v = &record.payload;
                if let Ok((def, _)) = parse_ask_body(v.get("def")) {
                    let def = StudyDef {
                        name: v.get("def").get("name").as_str().unwrap_or("default").into(),
                        ..def
                    };
                    let id = v.get("id").as_u64().unwrap_or(0);
                    let study = Study::new(id, def, 0.0);
                    let idx = inner.studies.len();
                    inner.by_key.insert(study.key.clone(), idx);
                    inner.studies.push(study);
                }
            }
            "trial_new" => {
                let v = &record.payload;
                let study_id = v.get("study_id").as_u64().unwrap_or(0);
                if let Some(t) = Trial::from_json(v.get("trial")) {
                    if let Some(si) =
                        inner.studies.iter().position(|s| s.id == study_id)
                    {
                        inner.next_trial_id = inner.next_trial_id.max(t.id + 1);
                        let ti = inner.studies[si].trials.len();
                        inner.trial_index.insert(t.id, (si, ti));
                        inner.studies[si].trials.push(t);
                    }
                }
            }
            "trial_tell" => {
                let v = &record.payload;
                if let (Some(id), Some(val)) =
                    (v.get("trial_id").as_u64(), v.get("value").as_f64())
                {
                    if let Some(&(si, ti)) = inner.trial_index.get(&id) {
                        let _ = inner.studies[si].trials[ti]
                            .complete(val, v.get("at").as_f64().unwrap_or(0.0));
                    }
                }
            }
            "trial_tell_mo" => {
                let v = &record.payload;
                if let (Some(id), Some(vals)) =
                    (v.get("trial_id").as_u64(), v.get("values").as_arr())
                {
                    let values: Vec<f64> = vals.iter().filter_map(Value::as_f64).collect();
                    if let Some(&(si, ti)) = inner.trial_index.get(&id) {
                        let _ = inner.studies[si].trials[ti]
                            .complete_mo(values, v.get("at").as_f64().unwrap_or(0.0));
                    }
                }
            }
            "trial_report" => {
                let v = &record.payload;
                if let (Some(id), Some(step), Some(val)) = (
                    v.get("trial_id").as_u64(),
                    v.get("step").as_u64(),
                    v.get("value").as_f64(),
                ) {
                    if let Some(&(si, ti)) = inner.trial_index.get(&id) {
                        let _ = inner.studies[si].trials[ti].report(step, val);
                    }
                }
            }
            "trial_prune" => {
                let v = &record.payload;
                if let Some(id) = v.get("trial_id").as_u64() {
                    if let Some(&(si, ti)) = inner.trial_index.get(&id) {
                        let _ = inner.studies[si].trials[ti]
                            .prune(v.get("at").as_f64().unwrap_or(0.0));
                    }
                }
            }
            "trial_fail" => {
                let v = &record.payload;
                if let Some(id) = v.get("trial_id").as_u64() {
                    if let Some(&(si, ti)) = inner.trial_index.get(&id) {
                        let _ = inner.studies[si].trials[ti]
                            .fail(v.get("at").as_f64().unwrap_or(0.0));
                    }
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use crate::testutil::TempDir;

    fn ask_body(study: &str) -> Value {
        parse(&format!(
            r#"{{
            "study_name": "{study}",
            "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
            "direction": "minimize",
            "sampler": {{"name": "random"}},
            "pruner": {{"name": "median", "min_trials": 2}}
        }}"#
        ))
        .unwrap()
    }

    #[test]
    fn ask_creates_study_then_joins_it() {
        let e = Engine::in_memory(EngineConfig::default());
        let r1 = e.ask(&ask_body("s")).unwrap();
        let r2 = e.ask(&ask_body("s")).unwrap();
        assert_eq!(r1.study_id, r2.study_id);
        assert_ne!(r1.trial_id, r2.trial_id);
        assert_eq!(r1.trial_number, 0);
        assert_eq!(r2.trial_number, 1);
        assert_eq!(e.n_studies(), 1);
        // Different definition → different study.
        let r3 = e.ask(&ask_body("other")).unwrap();
        assert_ne!(r3.study_id, r1.study_id);
        assert_eq!(e.n_studies(), 2);
    }

    #[test]
    fn ask_returns_in_domain_params() {
        let e = Engine::in_memory(EngineConfig::default());
        let r = e.ask(&ask_body("s")).unwrap();
        let x = r.params.get("x").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&x));
    }

    #[test]
    fn tell_finalizes_and_flags_best() {
        let e = Engine::in_memory(EngineConfig::default());
        let r1 = e.ask(&ask_body("s")).unwrap();
        let (sid, best1) = e.tell(r1.trial_id, 5.0).unwrap();
        assert_eq!(sid, r1.study_id);
        assert!(best1, "first completed is best");
        let r2 = e.ask(&ask_body("s")).unwrap();
        let (_, best2) = e.tell(r2.trial_id, 9.0).unwrap();
        assert!(!best2);
        let r3 = e.ask(&ask_body("s")).unwrap();
        let (_, best3) = e.tell(r3.trial_id, 1.0).unwrap();
        assert!(best3);
    }

    #[test]
    fn tell_twice_conflicts() {
        let e = Engine::in_memory(EngineConfig::default());
        let r = e.ask(&ask_body("s")).unwrap();
        e.tell(r.trial_id, 1.0).unwrap();
        assert!(matches!(e.tell(r.trial_id, 2.0), Err(ApiError::Conflict(_))));
    }

    #[test]
    fn tell_unknown_trial_not_found() {
        let e = Engine::in_memory(EngineConfig::default());
        assert!(matches!(e.tell(999, 1.0), Err(ApiError::NotFound(_))));
    }

    #[test]
    fn should_prune_records_and_decides() {
        let e = Engine::in_memory(EngineConfig::default());
        // Build a history of completed trials with loss 1.0 at step 1.
        for _ in 0..4 {
            let r = e.ask(&ask_body("s")).unwrap();
            e.should_prune(r.trial_id, 1, 1.0).unwrap();
            e.tell(r.trial_id, 1.0).unwrap();
        }
        // A terrible trial gets pruned.
        let bad = e.ask(&ask_body("s")).unwrap();
        let pruned = e.should_prune(bad.trial_id, 1, 100.0).unwrap();
        assert!(pruned);
        // Pruned trial can't be told.
        assert!(matches!(e.tell(bad.trial_id, 1.0), Err(ApiError::Conflict(_))));
        // A good trial survives.
        let good = e.ask(&ask_body("s")).unwrap();
        assert!(!e.should_prune(good.trial_id, 1, 0.5).unwrap());
    }

    #[test]
    fn deterministic_suggestions_per_seed() {
        let e1 = Engine::in_memory(EngineConfig::default());
        let e2 = Engine::in_memory(EngineConfig::default());
        for _ in 0..5 {
            let a = e1.ask(&ask_body("s")).unwrap();
            let b = e2.ask(&ask_body("s")).unwrap();
            assert_eq!(a.params.to_string(), b.params.to_string());
            e1.tell(a.trial_id, 1.0).unwrap();
            e2.tell(b.trial_id, 1.0).unwrap();
        }
    }

    #[test]
    fn durable_recovery_exact() {
        let d = TempDir::new("engine-recover");
        let (study_id, told, running_id);
        {
            let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
            let r1 = e.ask(&ask_body("s")).unwrap();
            study_id = r1.study_id;
            e.should_prune(r1.trial_id, 1, 0.9).unwrap();
            e.tell(r1.trial_id, 0.42).unwrap();
            told = r1.trial_id;
            let r2 = e.ask(&ask_body("s")).unwrap();
            running_id = r2.trial_id;
        }
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        assert_eq!(e.n_studies(), 1);
        let trials = e.trials_json(study_id).unwrap();
        let arr = trials.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let t0 = arr.iter().find(|t| t.get("id").as_u64() == Some(told)).unwrap();
        assert_eq!(t0.get("state").as_str(), Some("completed"));
        assert_eq!(t0.get("value").as_f64(), Some(0.42));
        let t1 = arr.iter().find(|t| t.get("id").as_u64() == Some(running_id)).unwrap();
        assert_eq!(t1.get("state").as_str(), Some("running"));
        // New trials continue the id sequence without collision.
        let r3 = e.ask(&ask_body("s")).unwrap();
        assert!(r3.trial_id > running_id);
    }

    #[test]
    fn recovery_after_compaction() {
        let d = TempDir::new("engine-compact");
        {
            let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
            for i in 0..6 {
                let r = e.ask(&ask_body("s")).unwrap();
                e.tell(r.trial_id, i as f64).unwrap();
            }
            e.compact().unwrap();
            let r = e.ask(&ask_body("s")).unwrap();
            e.tell(r.trial_id, -1.0).unwrap();
        }
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        let sid = e.study_id_by_key(
            &parse_ask_body(&ask_body("s")).unwrap().0.key(),
        );
        let sid = sid.unwrap();
        let trials = e.trials_json(sid).unwrap();
        assert_eq!(trials.as_arr().unwrap().len(), 7);
        let best = e.best_curve(sid).unwrap();
        assert_eq!(best.last().unwrap().1, -1.0);
    }

    #[test]
    fn reap_marks_stale_failed() {
        let mut cfg = EngineConfig::default();
        cfg.reap_after = Some(0.0); // everything is instantly stale
        let e = Engine::in_memory(cfg);
        let r = e.ask(&ask_body("s")).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(e.reap_stale(), 1);
        assert!(matches!(e.tell(r.trial_id, 1.0), Err(ApiError::Conflict(_))));
    }

    #[test]
    fn series_and_study_json() {
        let e = Engine::in_memory(EngineConfig::default());
        let r = e.ask(&ask_body("s")).unwrap();
        e.should_prune(r.trial_id, 1, 3.0).unwrap();
        e.should_prune(r.trial_id, 2, 2.0).unwrap();
        e.tell(r.trial_id, 2.0).unwrap();
        let series = e.series_json(r.study_id).unwrap();
        let pts = series.at(0).get("points");
        assert_eq!(pts.at(0).at(1).as_f64(), Some(3.0));
        assert_eq!(series.at(0).get("final").as_f64(), Some(2.0));
        let sj = e.study_json(r.study_id).unwrap();
        assert_eq!(sj.get("n_completed").as_i64(), Some(1));
        assert!(e.study_json(999).is_none());
    }
}
